"""Quickstart: train a reduced Llama-3.2 with SP-NGD for 30 steps on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController
from repro.data.synthetic import token_batches
from repro.models.transformer import DecoderLM


def main():
    cfg = get_config("llama3_2_1b").reduced()
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3))
    state = opt.init(params)
    ctrl = IntervalController(opt.stat_names(), alpha=0.1,
                              bytes_per_stat=opt.stat_bytes())

    data = token_batches(cfg.vocab, batch=8, seq_len=64, seed=0)
    step = jax.jit(opt.step)
    fast = jax.jit(opt.step_fast)

    for t in range(1, 31):
        batch = next(data)
        flags = ctrl.flags(t)
        if any(flags.values()):
            jflags = {k: jnp.asarray(v) for k, v in flags.items()}
            params, state, m = step(params, state, batch, jflags,
                                    1e-3, 2e-2, 0.9)
            sims = {k: (float(v[0]), float(v[1])) for k, v in m["sims"].items()}
            ctrl.update(t, flags, sims)
        else:
            params, state, m = fast(params, state, batch, 1e-3, 2e-2, 0.9)
            ctrl.update(t, flags, {})
        n_refresh = sum(flags.values())
        print(f"step {t:3d}  loss {float(m['loss']):.4f}  "
              f"refreshed {n_refresh}/{len(flags)} statistics")

    s = ctrl.summary()
    print(f"\nstatistics traffic: {s['total_stat_bytes'] / 1e6:.2f} MB vs "
          f"{s['dense_stat_bytes'] / 1e6:.2f} MB dense "
          f"(reduction to {100 * s['reduction_rate']:.1f}%)")


if __name__ == "__main__":
    main()
