"""The paper's exact training scheme at container scale: conv/BN net with
SP-NGD — empirical Fisher, unit-wise BN, adaptive stale statistics, running
mixup (Eq. 18-19), random erasing with zero value, polynomial LR decay
(Eq. 21), coupled momentum (Eq. 22), weight norm rescaling (Eq. 24).

    PYTHONPATH=src python examples/train_convnet_paper.py [--steps 120]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController
from repro.data.augment import RunningMixup, random_erase
from repro.data.synthetic import image_batches
from repro.models.resnet import ConvNet, ConvNetConfig
from repro.optim.schedules import polynomial_decay


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--damping", type=float, default=2.5e-4)
    ap.add_argument("--alpha-mixup", type=float, default=0.4)
    args = ap.parse_args()

    model = ConvNet(ConvNetConfig(widths=(16, 32), blocks_per_stage=2))
    params = model.init(jax.random.PRNGKey(0))
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts,
                NGDConfig(damping=args.damping, weight_rescale=True))
    state = opt.init(params)
    ctrl = IntervalController(opt.stat_names(), alpha=0.1,
                              bytes_per_stat=opt.stat_bytes())
    data = image_batches(10, args.batch, size=16, seed=0)
    mixup = RunningMixup(args.alpha_mixup, 10, seed=0)
    rng = np.random.RandomState(0)
    lr_fn = polynomial_decay(args.lr, 1, args.steps, 4.0)
    step_j = jax.jit(opt.step)
    fast_j = jax.jit(opt.step_fast)

    acc_hist = []
    for t in range(1, args.steps + 1):
        raw = next(data)
        imgs = jnp.asarray(random_erase(rng, np.asarray(raw["images"])))
        x, y = mixup(imgs, raw["labels"])
        batch = {"images": x, "labels": y}
        lr = lr_fn(t - 1)
        mom = 0.9 * lr / args.lr                      # Eq. 22
        flags = ctrl.flags(t)
        if any(flags.values()):
            jflags = {k: jnp.asarray(v) for k, v in flags.items()}
            params, state, m = step_j(params, state, batch, jflags,
                                      args.damping, lr, mom)
            sims = {k: (float(v[0]), float(v[1]))
                    for k, v in m["sims"].items()}
            ctrl.update(t, flags, sims)
        else:
            params, state, m = fast_j(params, state, batch,
                                      args.damping, lr, mom)
            ctrl.update(t, flags, {})
        # clean-data accuracy probe
        if t % 20 == 0 or t == 1:
            probe = next(data)
            logits = model.forward(params, probe["images"])
            acc = float((jnp.argmax(logits, -1) == probe["labels"]).mean())
            acc_hist.append(acc)
            print(f"step {t:4d} loss {float(m['loss']):.4f} "
                  f"acc {acc:.3f} lr {lr:.4f} "
                  f"refresh {sum(flags.values())}/{len(flags)}")

    s = ctrl.summary()
    print(f"\nfinal acc {acc_hist[-1]:.3f}; statistics traffic "
          f"{100 * s['reduction_rate']:.1f}% of refresh-every-step "
          f"(paper Table 2 'reduction')")


if __name__ == "__main__":
    main()
