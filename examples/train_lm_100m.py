"""End-to-end driver: train a ~100M-parameter llama-family model with the
full SP-NGD stack — microbatch accumulation, adaptive stale statistics,
polynomial LR decay with coupled momentum, checkpointing — for a few hundred
steps on the synthetic Markov LM task.

    PYTHONPATH=src python examples/train_lm_100m.py [--steps 200] [--sgd]

The ~100M config: 12L, d_model=768, 12 heads (GQA kv=4), d_ff=2048,
vocab=32768  ->  ~99M parameters.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.configs import get_config
from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController
from repro.data.synthetic import token_batches
from repro.launch.train import make_train_step, make_fast_step
from repro.models.transformer import DecoderLM
from repro.optim.schedules import polynomial_decay
from repro.optim.sgd import SGD


def build_model():
    base = get_config("llama3_2_1b")
    cfg = dataclasses.replace(
        base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=32768, kfac_max_dim=1024,
        dtype=jnp.float32, remat=False)
    return DecoderLM(cfg), cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--lr", type=float, default=2e-2)
    ap.add_argument("--damping", type=float, default=2.5e-4)
    ap.add_argument("--sgd", action="store_true", help="first-order baseline")
    ap.add_argument("--ckpt", default="/tmp/spngd_ckpt")
    args = ap.parse_args()

    model, cfg = build_model()
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params, vocab {cfg.vocab}")

    data = token_batches(cfg.vocab, args.batch, args.seq, seed=0)
    lr_fn = polynomial_decay(args.lr, 0, args.steps, 4.0)

    if args.sgd:
        opt = SGD(model.loss)
        state = opt.init(params)
        step_j = jax.jit(opt.step)
        for t in range(1, args.steps + 1):
            lr = lr_fn(t - 1)
            t0 = time.time()
            params, state, m = step_j(params, state, next(data), lr, 0.9)
            if t % 10 == 0 or t == 1:
                print(f"[sgd] step {t:4d} loss {float(m['loss']):.4f} "
                      f"lr {lr:.4f} ({time.time() - t0:.2f}s)")
        return

    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=args.damping))
    state = opt.init(params)
    ctrl = IntervalController(opt.stat_names(), alpha=0.1,
                              bytes_per_stat=opt.stat_bytes())
    train_j = jax.jit(make_train_step(model, opt, accum=args.accum))
    fast_j = jax.jit(make_fast_step(model, opt, accum=args.accum))

    for t in range(1, args.steps + 1):
        batch = next(data)
        lr = lr_fn(t - 1)
        mom = 0.9 * lr / args.lr          # Eq. 22 coupled momentum
        flags = ctrl.flags(t)
        t0 = time.time()
        if any(flags.values()):
            jflags = {k: jnp.asarray(v) for k, v in flags.items()}
            params, state, m = train_j(params, state, batch, jflags,
                                       args.damping, lr, mom)
            sims = {k: (float(v[0]), float(v[1]))
                    for k, v in m["sims"].items()}
            ctrl.update(t, flags, sims)
        else:
            params, state, m = fast_j(params, state, batch,
                                      args.damping, lr, mom)
            ctrl.update(t, flags, {})
        if t % 10 == 0 or t == 1:
            nref = sum(flags.values())
            print(f"[spngd] step {t:4d} loss {float(m['loss']):.4f} "
                  f"lr {lr:.4f} refresh {nref:2d}/{len(flags)} "
                  f"({time.time() - t0:.2f}s)")
        if t % 100 == 0:
            save_checkpoint(args.ckpt, t, params,
                            controller=ctrl.summary())
            print(f"checkpoint @ {t} -> {args.ckpt}")

    s = ctrl.summary()
    print(f"\nfinal loss {float(m['loss']):.4f}; statistic traffic reduced "
          f"to {100 * s['reduction_rate']:.1f}% of refresh-every-step")


if __name__ == "__main__":
    main()
