"""Serving example: prefill a batch of prompts, then batched greedy decode
against the KV cache (the serve_step lowered by the decode dry-run shapes).

    PYTHONPATH=src python examples/serve_decode.py [--arch llama3_2_1b]
    # flash-decode over the fp8 ring cache (window must be > 0):
    PYTHONPATH=src python examples/serve_decode.py --serve ring --window 16
"""
import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import DecoderLM
from repro.serve import ServeConfig, cache_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=None,
                    help="sliding window override (0 = full causal)")
    ap.add_argument("--serve", choices=["off", "ring", "dense"], default="off",
                    help="serving cache: ring = windowed ring buffer + "
                         "swa_decode flash kernel, dense = dense-f32 "
                         "fallback, off = the seed's dense decode path")
    ap.add_argument("--kv-dtype", default="fp8_e4m3",
                    choices=["f32", "fp8_e4m3", "fp8_e5m2"],
                    help="ring-cache payload storage (ignored for --serve "
                         "off/dense)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.window is not None:
        cfg = dataclasses.replace(cfg, sliding_window=args.window)
    serve = None
    if args.serve != "off":
        dtype = args.kv_dtype if args.serve == "ring" else "f32"
        serve = ServeConfig(kv_cache=args.serve, kv_dtype=dtype)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = jnp.asarray(
        rng.randint(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    max_len = args.prompt_len + args.gen
    prefill = jax.jit(functools.partial(model.prefill, max_len=max_len,
                                        serve=serve))
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready((logits, cache))
    t_prefill = time.time() - t0
    # host-syncing introspection stays OUTSIDE the timing window: int() on a
    # device array blocks on it, which would bill the sync to prefill
    clen = int(cache["len"].max()) if serve is not None else int(cache["len"])
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill:.2f}s -> cache len {clen}, "
          f"kv cache {cache_bytes(cache)} bytes")

    decode = jax.jit(functools.partial(model.decode_step, serve=serve))
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
    # warm up: the first call pays jit compilation; run it on a throwaway
    # result (decode is functional, the real cache is untouched) so the
    # timed loop below measures steady-state steps only
    jax.block_until_ready(decode(params, cache, tok))
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits1, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits1, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], 1)
    print(f"decoded {args.gen - 1} steps x batch {args.batch} in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("first sequence:", gen[0][:16], "...")
    assert np.isfinite(np.asarray(logits1, np.float32)).all()


if __name__ == "__main__":
    main()
