"""Serving example: prefill a batch of prompts, then batched greedy decode
against the KV cache (the serve_step lowered by the decode dry-run shapes).

    PYTHONPATH=src python examples/serve_decode.py [--arch llama3_2_1b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import DecoderLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = jnp.asarray(
        rng.randint(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    max_len = args.prompt_len + args.gen
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len))(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill:.2f}s -> cache len {int(cache['len'])}")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits1, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits1, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], 1)
    print(f"decoded {args.gen - 1} steps x batch {args.batch} in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("first sequence:", gen[0][:16], "...")
    assert np.isfinite(np.asarray(logits1, np.float32)).all()


if __name__ == "__main__":
    main()
