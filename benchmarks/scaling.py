"""Paper Fig. 5: time/step vs #devices for the distributed NGD variants.

No multi-TPU hardware exists in this container, so this benchmark combines
(a) REAL measured per-step component times from the CPU runs (forward/
backward, statistics construction for emp vs 1mc, inversion for unitBN vs
fullBN) with (b) the ring-collective cost model for the ReduceScatterV /
AllGatherV traffic (symmetric-packed bytes from the controller ledger).

    t(n) = t_fwdbwd + t_stats[est] + t_inv[bn] / n + t_comm(n)
    t_comm(n) = (bytes(n) * (n-1)/n) / link_bw + lat * ceil(log2 n)

The model-parallel inversion term / n is what produces the paper's
*superlinear* scaling region (1 -> 64 GPUs); the flat communication-bound
region beyond 128 reproduces Fig. 5's right half. Stats bytes scale with the
stale-statistics reduction rate measured by benchmarks/stale_reduction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import image_batch, make_convnet, row, time_fn
from repro.core.ngd import NGDConfig, SPNGD
from repro.optim.sgd import SGD

LINK_BW = 50e9       # bytes/s
LAT = 5e-6           # per-hop latency


def _measure_components(quick: bool):
    batch = image_batch(b=32 if quick else 128, size=16)
    model, params = make_convnet(widths=(8, 16), blocks=1)
    sgd = SGD(model.loss)
    t_fwdbwd = time_fn(jax.jit(sgd.step), params, sgd.init(params), batch,
                       0.1, 0.9)

    comps = {}
    for est, bn in (("emp", "unit"), ("1mc", "unit"), ("emp", "full")):
        m, p = make_convnet(widths=(8, 16), blocks=1, bn=bn)
        opt = SPNGD(m.loss, m.site_infos(), m.fstats, m.site_counts,
                    NGDConfig(damping=1e-3, estimator=est))
        st = opt.init(p)
        flags = {k: jnp.asarray(True) for k in opt.stat_names()}
        if est == "1mc":
            fn = jax.jit(lambda pp, ss, bb: opt.step(
                pp, ss, bb, flags, 1e-3, 0.05, 0.9, rng=jax.random.PRNGKey(0)))
        else:
            fn = jax.jit(lambda pp, ss, bb: opt.step(pp, ss, bb, flags,
                                                     1e-3, 0.05, 0.9))
        comps[(est, bn)] = time_fn(fn, p, st, batch)
        if (est, bn) == ("emp", "unit"):
            stat_bytes = sum(opt.stat_bytes().values())
            fast = jax.jit(lambda pp, ss, bb: opt.step_fast(
                pp, ss, bb, 1e-3, 0.05, 0.9))
            t_fast = time_fn(fast, p, st, batch)
    return t_fwdbwd, comps, stat_bytes, t_fast


def run(quick: bool = False):
    t_fb, comps, stat_bytes, t_fast = _measure_components(quick)
    # decompose: stats-construction overhead (est) and inversion (bn)
    t_stats = {"emp": max(comps[("emp", "unit")] - t_fb, 0.0),
               "1mc": max(comps[("1mc", "unit")] - t_fb, 0.0)}
    t_inv_extra = {"unit": 0.0,
                   "full": max(comps[("emp", "full")]
                               - comps[("emp", "unit")], 0.0)}
    # inversion share = refresh-step cost minus the no-refresh fast path
    t_inv_base = max(comps[("emp", "unit")] - t_fast, t_stats["emp"] * 0.3)

    out = [row("fig5.component_fwdbwd", t_fb, ""),
           row("fig5.component_stats_emp", t_stats["emp"], ""),
           row("fig5.component_stats_1mc", t_stats["1mc"], ""),
           row("fig5.component_fullBN_extra", t_inv_extra["full"], "")]

    def t_comm(n, bytes_):
        if n == 1:
            return 0.0
        import math
        return (bytes_ * (n - 1) / n / LINK_BW + LAT * math.log2(n)) * 1e6

    variants = {
        "emp+fullBN": ("emp", "full", 1.0),
        "emp+unitBN": ("emp", "unit", 1.0),
        "1mc+unitBN": ("1mc", "unit", 1.0),
        "emp+unitBN+stale": ("emp", "unit", 0.08),   # Table 2 reduction
    }
    devices = [1, 4, 16, 64, 256, 1024]
    for name, (est, bn, red) in variants.items():
        times = []
        for n in devices:
            inv = (t_inv_base + t_inv_extra[bn]) / n
            stats_t = t_stats[est] * red + 1e-6
            comm = t_comm(n, stat_bytes * red * n) / n + t_comm(
                n, stat_bytes * 0.1)
            times.append(t_fb + stats_t + inv + comm)
        derived = ";".join(f"n{n}={t:.0f}us" for n, t in zip(devices, times))
        out.append(row(f"fig5.projection.{name}", times[-1], derived))
        # superlinear check: time/step at 64 devices < at 1 device
        if name == "emp+fullBN":
            out.append(row("fig5.superlinear_1_to_64", 0.0,
                           f"speedup={times[0] / times[3]:.2f}x"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
