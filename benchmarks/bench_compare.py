"""Diff two BENCH_kernels.json snapshots and flag wall-clock regressions.

    PYTHONPATH=src python -m benchmarks.bench_compare OLD.json NEW.json \
        [--threshold 0.25] [--rows 'comm.*,stage4.*'] [--metric us]

For every row present in both snapshots, prints the old/new value of the
timing metric and the ratio new/old; rows whose ratio exceeds
``1 + threshold`` are marked REGRESSED and flip the exit code to 1 (the CI
gate). Ratio-style rows (``*_ratio`` / ``ratio`` fields, e.g.
``comm.ring_vs_dense.us_ratio``) are compared on the ratio itself — a ratio
row regresses when it GROWS past ``old * (1 + threshold)``, with an absolute
floor of +0.05 so noise around tiny ratios doesn't trip the gate.

Timing rows on CPU are interpret-mode measurements with real run-to-run
variance; the default 25% threshold is deliberately loose — the gate exists
to catch the 2-3x wall-clock regressions (like the pre-PR-6 ring hop loop),
not 10% noise.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import sys

# fields treated as the timing metric, in preference order
_TIMING_FIELDS = ("us",)
# fields that are themselves the tracked quantity on derived rows
_RATIO_FIELDS = ("us_ratio", "ratio", "flops_ratio", "wire_ratio")


def _match(name: str, rows: str) -> bool:
    """fnmatch against a COMMA-SEPARATED list of globs — fnmatch has no
    '{a,b}' brace expansion, and the CI gate spans several row families
    (comm.*, damped_inverse.*, stage4.*) in one invocation."""
    return any(fnmatch.fnmatch(name, pat)
               for pat in rows.split(",") if pat)


def load_results(path: str) -> dict:
    with open(path) as f:
        rec = json.load(f)
    return rec.get("results", rec)


def _metric(rec: dict, metric: str):
    """(kind, value) for one row: explicit --metric, else timing, else the
    first ratio-style field. None when the row carries neither."""
    if metric != "auto":
        v = rec.get(metric)
        return (None if v is None else ("explicit", float(v)))
    for f in _TIMING_FIELDS:
        if f in rec and rec[f]:
            return ("us", float(rec[f]))
    for f in _RATIO_FIELDS:
        if f in rec:
            return (f, float(rec[f]))
    return None


def compare(old: dict, new: dict, threshold: float, rows: str,
            metric: str = "auto") -> tuple[list, list]:
    """Returns (report_lines, regressed_names)."""
    lines, regressed = [], []
    names = sorted(set(old) & set(new))
    matched = [n for n in names if _match(n, rows)]
    for name in matched:
        mo = _metric(old[name], metric)
        mn = _metric(new[name], metric)
        if mo is None or mn is None or mo[0] != mn[0]:
            continue
        kind, vo = mo
        _, vn = mn
        ratio = vn / vo if vo else float("inf")
        if kind in _RATIO_FIELDS:
            # derived-ratio rows regress when the tracked ratio grows;
            # +0.05 absolute floor keeps noise around small ratios quiet
            bad = vn > max(vo * (1.0 + threshold), vo + 0.05)
            lines.append(f"{name:40s} {kind}: {vo:8.3f} -> {vn:8.3f} "
                         f"({ratio:5.2f}x){'  REGRESSED' if bad else ''}")
        else:
            bad = ratio > 1.0 + threshold
            lines.append(f"{name:40s} us: {vo:10.1f} -> {vn:10.1f} "
                         f"({ratio:5.2f}x){'  REGRESSED' if bad else ''}")
        if bad:
            regressed.append(name)
    dropped = [n for n in sorted(set(old) - set(new))
               if _match(n, rows)]
    for name in dropped:
        lines.append(f"{name:40s} MISSING from new snapshot")
    return lines, regressed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_kernels.json snapshots")
    ap.add_argument("old", help="baseline snapshot (e.g. the committed one)")
    ap.add_argument("new", help="freshly measured snapshot")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed fractional growth before a row is a "
                         "regression (default 0.25 = +25%%)")
    ap.add_argument("--rows", default="*",
                    help="comma-separated globs over row names "
                         "(e.g. 'comm.*,damped_inverse.*,stage4.*')")
    ap.add_argument("--metric", default="auto",
                    help="force one field (e.g. us, wire_bytes) instead of "
                         "the auto timing/ratio pick")
    args = ap.parse_args(argv)

    old = load_results(args.old)
    new = load_results(args.new)
    lines, regressed = compare(old, new, args.threshold, args.rows,
                               args.metric)
    if not lines:
        print(f"no rows matched {args.rows!r} in both snapshots",
              file=sys.stderr)
        return 2
    for ln in lines:
        print(ln)
    if regressed:
        print(f"\n{len(regressed)} row(s) regressed past "
              f"+{args.threshold:.0%}: {', '.join(regressed)}",
              file=sys.stderr)
        return 1
    print(f"\nOK: no row regressed past +{args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
