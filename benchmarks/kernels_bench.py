"""Kernel micro-benchmarks: Pallas kernels vs pure-jnp oracles.

On CPU the Pallas kernels run in interpret mode (Python emulation) so their
wall time is NOT indicative of TPU performance; we report the jnp-oracle
time as the timing column and the kernel-vs-oracle max |err| as the derived
column (the correctness contract the TPU kernel must meet).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.kernels import ops, ref


def run(quick: bool = False):
    out = []
    rng = np.random.RandomState(0)
    n, d = (256, 128) if quick else (1024, 256)

    x = jnp.asarray(rng.randn(n, d), jnp.bfloat16)
    t = time_fn(jax.jit(ref.kfac_factor_ref), x)
    err = float(jnp.max(jnp.abs(
        ops.kfac_factor(x, bm=64, bn=64, bk=128, interpret=True)
        - ref.kfac_factor_ref(x))))
    out.append(row("kernel.kfac_factor_syrk", t, f"maxerr={err:.2e}"))

    nb, b, m = (2, 64, 64) if quick else (4, 128, 128)
    binv = jnp.asarray(rng.randn(nb, b, b), jnp.float32)
    w = jnp.asarray(rng.randn(nb, b, m), jnp.float32)
    t = time_fn(jax.jit(ref.block_precond_ref), binv, w)
    err = float(jnp.max(jnp.abs(
        ops.kfac_block_precond(binv, w, bm=32, bn=32, bk=32, interpret=True)
        - ref.block_precond_ref(binv, w))))
    out.append(row("kernel.kfac_block_precond", t, f"maxerr={err:.2e}"))

    bh, s, hd, win = (2, 64, 32, 16) if quick else (4, 128, 64, 32)
    q = jnp.asarray(rng.randn(bh, s, hd), jnp.float32)
    k = jnp.asarray(rng.randn(bh, s, hd), jnp.float32)
    v = jnp.asarray(rng.randn(bh, s, hd), jnp.float32)
    t = time_fn(jax.jit(lambda q, k, v: ref.swa_attention_ref(
        q, k, v, window=win)), q, k, v)
    err = float(jnp.max(jnp.abs(
        ops.swa_attention(q, k, v, window=win, bq=32, bk=32, interpret=True)
        - ref.swa_attention_ref(q, k, v, window=win))))
    out.append(row("kernel.swa_attention", t, f"maxerr={err:.2e}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
