"""Kernel micro-benchmarks + end-to-end backend A/B.

Micro section: Pallas kernels vs pure-jnp oracles. On CPU the Pallas kernels
run in interpret mode (Python emulation) so their wall time is NOT indicative
of TPU performance; we report the jnp-oracle time as the timing column and
the kernel-vs-oracle max |err| as the derived column (the correctness
contract the TPU kernel must meet).

Attention-backward A/B: the retired recompute-through-ref custom VJP
(rebuilt locally as the baseline) against the fused dq/dk/dv Pallas backward
now on the training path, compared by XLA cost-analysis FLOPs of the full
gradient computation (identical forwards, so the delta is the backward) and
by wall time. The FLOP counts are the durable signal on CPU — interpret-mode
wall time is Python emulation.

E2E section: a full SP-NGD ``train_step`` timed once per dispatch backend
(``ref`` vs ``pallas``), so every PR records the step-time delta of routing
the hot paths through the kernels. ``run()`` also stashes the measurements in
``LAST_RESULTS`` for the JSON emitter in ``benchmarks.run``.
"""

from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.kernels import ops, ref

# filled by run(): {"kernel.<name>": {"us": ..., "maxerr": ...},
#                   "train_step.<backend>": {"us": ..., "loss": ...}}
LAST_RESULTS: dict = {}


def _bench_train_step(backend: str, quick: bool):
    from repro.configs import get_config
    from repro.core.ngd import NGDConfig, SPNGD
    from repro.launch.train import make_train_step
    from repro.models.transformer import DecoderLM

    cfg = get_config("llama3_2_1b").reduced(
        head_dim=32, d_ff=128, vocab=256, sliding_window=8)
    cfg = dataclasses.replace(cfg, backend=backend)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3, backend=backend))
    state = opt.init(params)
    rng = np.random.RandomState(0)
    b, s = (4, 16) if quick else (8, 32)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    step = jax.jit(make_train_step(model, opt))

    def call():
        p, st, m = step(params, state, batch, flags, 1e-3, 5e-3, 0.9)
        return m["loss"]

    t = time_fn(call, warmup=1, iters=3 if quick else 5)
    loss = float(call())
    return t, loss


def _bench_obs(quick: bool):
    """A/B of the telemetry cost (repro.obs): the SAME fast-step training
    loop — per-step block_until_ready in both arms so only the logger work
    differs — with the MetricsLogger enabled (JSONL to a temp file, per-step
    events with scalar fetches + ledger drain, exactly what a
    ``--metrics-jsonl`` run pays) vs disabled (the default no-op path). The
    fast step is the cheapest step, so the ratio is the most conservative
    reading of the <3% instrumentation budget. Returns per-step us for both
    arms; alternating repetitions, medians."""
    import tempfile
    import time as _time

    from repro.configs import get_config
    from repro.core.ngd import NGDConfig, SPNGD
    from repro.core.stale import IntervalController
    from repro.launch.train import make_fast_step
    from repro.models.transformer import DecoderLM
    from repro.obs import MetricsLogger

    cfg = get_config("llama3_2_1b").reduced(
        head_dim=32, d_ff=128, vocab=256, sliding_window=8)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3))
    state = opt.init(params)
    rng = np.random.RandomState(0)
    b, s = (4, 16) if quick else (8, 32)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    fast = jax.jit(make_fast_step(model, opt))
    steps = 10 if quick else 20

    def loop(log):
        ctrl = IntervalController(opt.stat_names(),
                                  bytes_per_stat=opt.stat_bytes())
        none = {k: False for k in opt.stat_names()}
        p, st = params, state
        t_start = _time.perf_counter()
        for t in range(1, steps + 1):
            t0 = _time.perf_counter()
            p, st, m = fast(p, st, batch, 1e-3, 5e-3, 0.9)
            ctrl.update(t, none, {})
            jax.block_until_ready(m["loss"])
            dt = _time.perf_counter() - t0
            if log.enabled:
                log.log_step(t, loss=float(m["loss"]), dt=dt, kind="fast",
                             grad_norm=float(m["grad_norm"]),
                             update_norm=float(m["update_norm"]),
                             comm=ctrl.drain())
        return (_time.perf_counter() - t_start) * 1e6 / steps

    jax.block_until_ready(
        fast(params, state, batch, 1e-3, 5e-3, 0.9)[2]["loss"])  # compile
    off_times, on_times = [], []
    with tempfile.TemporaryDirectory() as tmp:
        for i in range(3):
            off_times.append(loop(MetricsLogger()))
            with MetricsLogger(os.path.join(tmp, f"obs_{i}.jsonl")) as log:
                on_times.append(loop(log))
    off = sorted(off_times)[1]
    on = sorted(on_times)[1]
    return {"disabled_us": off, "enabled_us": on, "ratio": on / off,
            "steps": steps}


def _bench_attn_bwd(quick: bool):
    """A/B the attention backward: recompute-through-ref VJP (the scheme
    this repo shipped before the fused kernels) vs the fused Pallas
    dq/dk/dv backward. Returns {name: {us, flops, bwd_flops}}."""
    from repro.launch import compat
    from repro.models import attention as attn_lib

    b, s, h, kv, hd, w = ((2, 64, 4, 2, 16, 16) if quick
                          else (2, 128, 8, 2, 32, 32))
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kv, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kv, hd), jnp.float32)

    # the retired scheme, rebuilt as the baseline: Pallas forward, backward
    # re-runs the whole chunked ref attention under jax.vjp
    @functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
    def recompute_attn(q, k, v, window):
        return attn_lib.attention(q, k, v, window=window, backend="pallas")

    def _fwd(q, k, v, window):
        return recompute_attn(q, k, v, window), (q, k, v)

    def _bwd(window, res, g):
        q, k, v = res
        _, vjp = jax.vjp(lambda q, k, v: attn_lib.attention(
            q, k, v, causal=True, window=window, backend="ref"), q, k, v)
        return vjp(g)

    recompute_attn.defvjp(_fwd, _bwd)

    def loss_recompute(q, k, v):
        return jnp.sum(recompute_attn(q, k, v, w) ** 2)

    def loss_fused(q, k, v):
        return jnp.sum(attn_lib.attention(q, k, v, window=w,
                                          backend="pallas") ** 2)

    out = {}
    fwd_flops = None
    for name, loss in (("recompute", loss_recompute), ("fused", loss_fused)):
        if fwd_flops is None:
            cf = jax.jit(loss).lower(q, k, v).compile()
            fwd_flops = compat.cost_analysis(cf).get("flops", 0.0)
        g = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
        cg = g.lower(q, k, v).compile()
        flops = compat.cost_analysis(cg).get("flops", 0.0)
        t = time_fn(g, q, k, v, warmup=1, iters=3)
        out[name] = {"us": t, "flops": flops,
                     "bwd_flops": max(flops - fwd_flops, 0.0)}
    return out


def _bench_damped_inverse(quick: bool):
    """A/B the Stage-4 inversion: ref eigh (the LAPACK/XLA factorization
    path — not matmul-shaped, the paper's non-GEMM bottleneck) vs the
    blocked Newton-Schulz Pallas kernel (matmul-only; interpret mode on
    CPU). cost-analysis FLOPs are the durable column: the NS figure counts
    real GEMM work the MXU would run, while eigh's custom-call largely
    hides from the counter — the wall-time ratio on CPU is the honest
    comparison, the FLOP column documents that NS is pure countable
    matmuls. Returns {name: {us, flops, maxerr...}}."""
    from repro.kernels import dispatch
    from repro.launch import compat

    nb, b = (2, 64) if quick else (4, 128)
    rng = np.random.RandomState(0)
    q = np.linalg.qr(rng.randn(nb, b, b))[0]
    lam = np.logspace(0, -3, b)                       # damped kappa ~1e3
    f = jnp.asarray(np.einsum("kab,b,kcb->kac", q, lam, q), jnp.float32)
    d = jnp.asarray(1e-3)

    fns = {
        "eigh": jax.jit(lambda f, d: dispatch.damped_inverse(
            f, d, method="eigh", backend="ref")),
        "newton_schulz": jax.jit(lambda f, d: dispatch.damped_inverse(
            f, d, method="newton_schulz", backend="pallas")),
    }
    out = {}
    for name, fn in fns.items():
        cf = fn.lower(f, d).compile()
        flops = compat.cost_analysis(cf).get("flops", 0.0)
        out[name] = {"us": time_fn(fn, f, d, warmup=1, iters=3),
                     "flops": flops}
    err = float(jnp.max(jnp.abs(fns["newton_schulz"](f, d)
                                - fns["eigh"](f, d))))
    out["newton_schulz"]["maxerr"] = err
    return out


def _bench_serve(quick: bool):
    """Serving decode A/B on the reduced llama: the seed's dense-cache
    decode step vs the flash-decode step over the fp8 ring cache.

    Baseline per the `_bench_attn_bwd` precedent (the retired scheme,
    rebuilt locally): the seed decoded through the FULL ``max_len``-padded
    dense cache every step — masked, but full FLOPs/bandwidth. This PR's
    clamp trims the live path, so the unclamped walk is reconstructed with
    a ``window=0`` config (identical compute shapes to the seed's masked
    windowed walk — the window only changes the mask, not the contraction).
    Flash arm: ring cache of capacity ``window`` + ``swa_decode``. Both
    arms time the jitted ``decode_step`` on the ref backend (repo
    convention: jnp is the reported timing column on CPU; interpret-mode
    Pallas wall time is Python emulation). Returns {name: rec}."""
    from repro.configs import get_config
    from repro.models.transformer import DecoderLM
    from repro.serve import ServeConfig, cache_bytes

    b, plen = 8, 16
    max_len, win = (2048, 128) if quick else (4096, 256)
    rng = np.random.RandomState(0)
    prompts = jnp.asarray(rng.randint(0, 256, (b, plen)), jnp.int32)

    def build(window, serve):
        cfg = get_config("llama3_2_1b").reduced(
            head_dim=32, d_ff=128, vocab=256, sliding_window=window)
        cfg = dataclasses.replace(cfg, backend="ref")
        model = DecoderLM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prefill = jax.jit(functools.partial(model.prefill, max_len=max_len,
                                            serve=serve))
        logits, cache = prefill(params, {"tokens": prompts})
        tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
        step = jax.jit(functools.partial(model.decode_step, serve=serve))
        return model, params, cache, tok, step

    _, params, cache, tok, step = build(0, None)
    t_dense = time_fn(step, params, cache, tok, warmup=1, iters=3)

    serve = ServeConfig(kv_cache="ring", kv_dtype="fp8_e4m3", backend="ref")
    model, params, cache, tok, step = build(win, serve)
    t_flash = time_fn(step, params, cache, tok, warmup=1, iters=3)

    fp8_b = cache_bytes(cache)
    f32_b = cache_bytes(model.init_cache(
        b, max_len, serve=ServeConfig(kv_cache="ring", kv_dtype="f32")))
    dense_b = cache_bytes(model.init_cache(b, max_len))
    return {
        "serve.decode_dense": {"us": t_dense, "max_len": max_len,
                               "batch": b},
        "serve.decode_flash": {"us": t_flash, "window": win, "batch": b},
        # acceptance gauge: flash decode <= 0.5x the dense walk at
        # window <= max_len/4 (here max_len/16)
        "serve.decode_flash_over_dense": {
            "us_ratio": t_flash / t_dense,
            "max_len": max_len, "window": win,
        },
        # acceptance gauge: fp8 ring payload <= 0.3x the f32 ring cache at
        # the SAME capacity (isolates the codec from the window sizing;
        # f32_dense_bytes documents the combined ring+fp8 saving)
        "serve.kv_fp8_over_f32": {
            "ratio": fp8_b / f32_b,
            "fp8_ring_bytes": fp8_b, "f32_ring_bytes": f32_b,
            "f32_dense_bytes": dense_b,
        },
    }


def _bench_in_subprocess(flag: str, local_fn, quick: bool, what: str):
    """Run a multi-device A/B body in a SUBPROCESS with 8 virtual CPU
    devices so the collectives are real multi-device programs — setting the
    device count in this process would oversubscribe the CPU and skew every
    other benchmark row's timing (the cross-PR A/B ratios in
    BENCH_kernels.json must stay comparable). Falls back to an in-process
    run on whatever devices exist if the subprocess fails."""
    import json
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    os.environ.get("PYTHONPATH", "")) if p)
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.kernels_bench",
             flag] + (["--quick"] if quick else []),
            env=env, cwd=root, capture_output=True, text=True, check=True)
        return json.loads(proc.stdout.splitlines()[-1])
    except (subprocess.CalledProcessError, ValueError, IndexError) as e:
        print(f"# {what} A/B subprocess failed ({e}); running in-process on "
              f"{len(jax.devices())} device(s)", file=sys.stderr)
        return local_fn(quick)


def _bench_comm(quick: bool):
    """Stage-3 strategy A/B (repro.comm) on 8 virtual devices."""
    return _bench_in_subprocess("--comm-json", _bench_comm_local, quick,
                                "comm")


def _bench_stage4(quick: bool):
    """Stage-4 refresh A/B (replicated vs sharded inversion) on 8 virtual
    devices."""
    return _bench_in_subprocess("--stage4-json", _bench_stage4_local, quick,
                                "stage4")


def _bench_overlap(quick: bool):
    """Chunked-refresh-pipeline vs inline-refresh A/B (ISSUE-10) on 8
    virtual devices."""
    return _bench_in_subprocess("--overlap-json", _bench_overlap_local,
                                quick, "overlap")


def _bench_overlap_local(quick: bool):
    """The refresh-overlap A/B body: the reduced llama under the shard_map
    schedule, refreshing every statistic either INLINE (the double-buffer
    refresh pays Stage-2/3 + every Stage-4 inversion in one step — the
    latency spike the pipeline exists to remove) or CHUNKED over K fast
    steps (``refresh_chunks=K``: the capture step pays Stage-2/3 only, each
    drain step fuses ~1/K of the inversions + gathers).

    The tracked quantity is the PEAK per-step surcharge over the arm's own
    idle fast-step baseline across one refresh cycle — the worst step a
    training loop actually observes. Each arm measures its own baseline
    because the pipelined fast step carries the chunk switch in its program.
    Two unmeasured warmup cycles per arm flush first-execution effects
    (compile, the one extra retrace the first post-cycle state signature
    triggers, LAPACK thread spin-up) before the timed cycles.

    ``stage4.overlap_over_inline.us_ratio`` is the acceptance gauge: the
    overlapped peak must come in under 0.3x the inline spike (K=4 with a
    balanced chunk schedule predicts ~0.25x + capture cost). Returns
    {name: rec}."""
    import time

    from repro.configs import get_config
    from repro.core.ngd import NGDConfig, SPNGD
    from repro.launch import compat
    from repro.launch.train import (make_shardmap_fast_step,
                                    make_shardmap_train_step)
    from repro.models.transformer import DecoderLM

    ndev = len(jax.devices())
    chunks = 4
    reps = 2 if quick else 3
    b, s = (4, 16) if quick else (8, 16)
    if ndev >= 4 and ndev % 2 == 0:
        mesh = compat.make_mesh((ndev // 2, 2), ("data", "model"))
    else:                                  # in-process fallback: tiny mesh
        mesh = compat.make_mesh((ndev, 1), ("data", "model"))
    dp_n = mesh.shape["data"]
    b = max(b, dp_n)

    def build(k):
        cfg = get_config("llama3_2_1b").reduced(
            head_dim=32, d_ff=128, vocab=256, sliding_window=8)
        cfg = dataclasses.replace(cfg, backend="ref")
        model = DecoderLM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                    model.site_counts,
                    NGDConfig(damping=1e-3, backend="ref",
                              double_buffer=True, refresh_chunks=k))
        state = opt.init(params)
        step = jax.jit(make_shardmap_train_step(model, opt, mesh))
        fast = jax.jit(make_shardmap_fast_step(model, opt, mesh))
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                       jnp.int32)}
        flags = {n: jnp.asarray(True) for n in opt.stat_names()}
        return params, state, batch, flags, step, fast

    def timed(fn, *a):
        t0 = time.perf_counter()
        out = fn(*a)
        jax.block_until_ready(out[2]["loss"])
        return (time.perf_counter() - t0) * 1e6, out

    def measure(k):
        params, state, batch, flags, step, fast = build(k)
        p, st = params, state

        def cycle():
            # one capture + k drain/flip steps + 2 guaranteed-idle steps
            nonlocal p, st
            dt, (p, st, m) = timed(step, p, st, batch, flags,
                                   1e-3, 5e-3, 0.9)
            cap = dt
            drain, idle = [], []
            for _ in range(k + 3):
                dt, (p, st, m) = timed(fast, p, st, batch, 1e-3, 5e-3, 0.9)
                if int(m.get("refresh_inflight", 0)) > 0:
                    drain.append(dt)
                else:
                    idle.append(dt)
            return cap, drain, idle

        # warmup: TWO cycles — the first compiles, the second flushes the
        # one extra retrace the first post-cycle state signature triggers
        # (weak-type stabilization) plus LAPACK thread spin-up
        cycle()
        cycle()
        caps, drains, idles, peaks = [], [], [], []
        for _ in range(reps):
            cap, drain, idle = cycle()
            caps.append(cap)
            drains.extend(drain)
            idles.extend(idle)
            peaks.append(max([cap] + drain) if drain else cap)
        base = float(np.median(idles))
        # min over reps of the per-cycle peak: still a true observation of
        # the worst step in a cycle, but robust to a background process
        # landing on one rep (max-of-noisy-samples inflates under load)
        return {"refresh_us": float(np.median(caps)),
                "drain_us": float(np.median(drains)) if drains else 0.0,
                "fast_us": base,
                "peak_surcharge_us": max(float(np.min(peaks)) - base, 1.0)}

    inline = measure(1)
    pipe = measure(chunks)
    ratio = pipe["peak_surcharge_us"] / inline["peak_surcharge_us"]
    return {
        "stage4.refresh_inline_spike": {
            "us": inline["peak_surcharge_us"],
            "step_us": inline["refresh_us"], "fast_us": inline["fast_us"],
            "devices": ndev,
        },
        "stage4.refresh_overlapped_peak": {
            "us": pipe["peak_surcharge_us"], "chunks": chunks,
            "capture_us": pipe["refresh_us"], "drain_us": pipe["drain_us"],
            "fast_us": pipe["fast_us"], "devices": ndev,
        },
        # acceptance gauge: overlapped per-step overhead < 0.3x the inline
        # refresh spike
        "stage4.overlap_over_inline": {
            "us_ratio": ratio, "chunks": chunks, "devices": ndev,
        },
    }


def _bench_comm_local(quick: bool):
    """The comm A/B body: reduce one synthetic raw-stats tree over every
    available device with each strategy under shard_map, reporting wall
    time, max |err| vs the dense psum_scatter baseline, and the reducer's
    wire-byte accounting (the durable column on CPU — wall time here is
    interpret-mode collectives over virtual devices). Returns {name: rec}."""
    from jax.sharding import PartitionSpec as P

    from repro.comm import FactorReducer, make_comm_config
    from repro.launch import compat

    ndev = len(jax.devices())
    mesh = compat.make_mesh((ndev,), ("data",))
    nb, b = (2, 32) if quick else (4, 64)
    lead = 2 * ndev                      # scatters over the data axis
    template = {"fam": {
        "a": jax.ShapeDtypeStruct((lead, nb, b, b), jnp.float32),
        "d": jax.ShapeDtypeStruct((lead, nb * b), jnp.float32),
    }}
    rng = np.random.RandomState(0)
    f = rng.randn(ndev, lead, nb, b, b).astype(np.float32)
    raw_all = {"fam": {
        "a": jnp.asarray(f + np.swapaxes(f, -1, -2)),
        "d": jnp.asarray(rng.randn(ndev, lead, nb * b), np.float32) ** 2,
    }}

    # hier models the 8 virtual devices as 2 hosts x (ndev/2) devices so
    # both levels (intra psum_scatter + inter fp8 ring) run
    dph = max(ndev // 2, 1)
    cfgs = {
        "dense": make_comm_config("dense"),
        "ring": make_comm_config("ring"),
        "ring_fp8": make_comm_config("ring_fp8"),
        "hier": make_comm_config("hier", devices_per_host=dph),
    }
    out = {}
    results = {}
    for strat, cfg in cfgs.items():
        red = FactorReducer(mesh, comm=cfg, template=template,
                            sym_fn=lambda fam, key: key == "a")

        def body(raw):
            return red.reduce(jax.tree.map(lambda x: x[0], raw))

        in_specs = jax.tree.map(lambda _: P("data"), raw_all)
        fn = jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=(in_specs,),
            out_specs=red.out_specs(), axis_names={"data"}))
        t = time_fn(fn, raw_all, warmup=1, iters=3)
        results[strat] = jax.tree.map(np.asarray, fn(raw_all))
        out[f"comm.reduce_{strat}"] = {
            "us": t,
            "wire_bytes": sum(red.wire_bytes_per_stat().values()),
        }
        if strat == "hier":
            levels = red.wire_bytes_per_stat_levels().values()
            out["comm.reduce_hier"]["intra_wire_bytes"] = sum(
                i for i, _ in levels)
            out["comm.reduce_hier"]["inter_wire_bytes"] = sum(
                j for _, j in levels)
    for strat in ("ring", "ring_fp8", "hier"):
        err = max(float(np.max(np.abs(a - d))) for a, d in zip(
            jax.tree.leaves(results[strat]),
            jax.tree.leaves(results["dense"])))
        out[f"comm.reduce_{strat}"]["maxerr_vs_dense"] = err
    wd = out["comm.reduce_dense"]["wire_bytes"]
    out["comm.ring_vs_dense"] = {
        "wire_ratio": out["comm.reduce_ring"]["wire_bytes"] / wd,
        "us_ratio": (out["comm.reduce_ring"]["us"]
                     / out["comm.reduce_dense"]["us"]),
        "maxerr": out["comm.reduce_ring"]["maxerr_vs_dense"],
        "devices": ndev,
    }
    # acceptance gauge: fp8 wire <= 0.3x the dense f32 collective payload
    out["comm.wire_fp8_over_f32"] = {
        "ratio": out["comm.reduce_ring_fp8"]["wire_bytes"] / wd,
        "fp8_wire_bytes": out["comm.reduce_ring_fp8"]["wire_bytes"],
        "f32_dense_wire_bytes": wd,
        "maxerr": out["comm.reduce_ring_fp8"]["maxerr_vs_dense"],
    }
    # acceptance gauge: hier's inter-host level <= 0.2x dense f32
    out["comm.hier_inter_over_dense"] = {
        "ratio": out["comm.reduce_hier"]["inter_wire_bytes"] / wd,
        "inter_wire_bytes": out["comm.reduce_hier"]["inter_wire_bytes"],
        "intra_wire_bytes": out["comm.reduce_hier"]["intra_wire_bytes"],
        "f32_dense_wire_bytes": wd,
        "devices_per_host": dph,
        "maxerr": out["comm.reduce_hier"]["maxerr_vs_dense"],
    }

    # fused: the reducer consumes PRE-PACKED wire payloads (what the fused
    # SYRK epilogue emits); quantize once per source here, exactly as the
    # kernel would, then reduce the {"payload","scale"} tree
    from repro import quant
    from repro.core import kfac
    pay, sc = quant.quantize_rows(
        kfac.sym_pack(raw_all["fam"]["a"]), "e4m3", "fp32")
    raw_wire = {"fam": {"a": {"payload": pay, "scale": sc},
                        "d": raw_all["fam"]["d"]}}
    template_w = {"fam": {
        "a": {"payload": jax.ShapeDtypeStruct(pay.shape[1:], pay.dtype),
              "scale": jax.ShapeDtypeStruct(sc.shape[1:], sc.dtype)},
        "d": template["fam"]["d"],
    }}
    red = FactorReducer(mesh, comm=make_comm_config("fused"),
                        template=template_w,
                        sym_fn=lambda fam, key: key == "a")

    def body_w(raw):
        return red.reduce(jax.tree.map(lambda x: x[0], raw))

    in_specs = jax.tree.map(lambda _: P("data"), raw_wire)
    fn = jax.jit(compat.shard_map(
        body_w, mesh=mesh, in_specs=(in_specs,),
        out_specs=red.out_specs(), axis_names={"data"}))
    t = time_fn(fn, raw_wire, warmup=1, iters=3)
    res = jax.tree.map(np.asarray, fn(raw_wire))
    err = max(float(np.max(np.abs(a - d))) for a, d in zip(
        jax.tree.leaves(res), jax.tree.leaves(results["dense"])))
    out["comm.reduce_fused"] = {
        "us": t,
        "wire_bytes": sum(red.wire_bytes_per_stat().values()),
        "maxerr_vs_dense": err,
    }
    return out


def _bench_stage4_local(quick: bool):
    """The Stage-4 A/B body: invert one scattered stack of SPD factor
    blocks with the pre-PR-7 refresh (every device redundantly inverts the
    FULL stack — modelled as a shard_map over a replicated operand, which
    is exactly what the monolithic refresh compiled to) vs the sharded
    ``Stage4Inverter`` refresh (each device inverts only its
    ``FactorReducer``-owned chunk, then all-gathers the sym-packed f32
    preconditioners). The wall-clock ratio is the acceptance gauge: the
    sharded refresh does 1/p of the eigh work per device, so it must come
    in well under the replicated baseline even after paying for the
    gather. Returns {name: rec}."""
    import functools

    from jax.sharding import PartitionSpec as P

    from repro.comm import FactorReducer, Stage4Inverter, make_comm_config
    from repro.kernels import dispatch
    from repro.launch import compat

    ndev = len(jax.devices())
    mesh = compat.make_mesh((ndev,), ("data",))
    lead, b = (ndev, 48) if quick else (2 * ndev, 96)
    rng = np.random.RandomState(0)
    q = np.linalg.qr(rng.randn(lead, b, b))[0]
    lam = np.logspace(0, -3, b)                       # damped kappa ~1e3
    f = jnp.asarray(np.einsum("kab,b,kcb->kac", q, lam, q), jnp.float32)
    damp = jnp.full((lead,), 1e-3, jnp.float32)

    template = {"fam": {"a": jax.ShapeDtypeStruct((lead, b, b),
                                                  jnp.float32)}}
    red = FactorReducer(mesh, comm=make_comm_config("dense"),
                        template=template, sym_fn=lambda fam, key: True)
    inv4 = Stage4Inverter(red, method="eigh", backend="ref")

    def repl_body(s, d):
        # d (lead,) already matches the 3-D stat's batch dims
        return dispatch.damped_inverse(s, d, method="eigh", backend="ref")

    repl = jax.jit(compat.shard_map(
        repl_body, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
        axis_names={"data"}))
    shard = jax.jit(functools.partial(inv4.invert, fam="fam", key="a"))

    t_repl = time_fn(repl, f, damp, warmup=1, iters=3)
    t_shard = time_fn(shard, f, damp, warmup=1, iters=3)
    err = float(jnp.max(jnp.abs(shard(f, damp) - repl(f, damp))))
    gather = sum(red.gather_bytes_per_stat().values())
    return {
        "stage4.refresh_replicated": {"us": t_repl, "devices": ndev},
        "stage4.refresh_sharded": {"us": t_shard, "devices": ndev,
                                   "gather_bytes": gather,
                                   "maxerr_vs_replicated": err},
        # acceptance gauge: sharded refresh wall clock < 0.6x replicated
        "stage4.sharded_over_replicated": {
            "us_ratio": t_shard / t_repl,
            "devices": ndev,
            "gather_bytes": gather,
            "maxerr": err,
        },
    }


def run(quick: bool = False):
    out = []
    LAST_RESULTS.clear()
    rng = np.random.RandomState(0)
    n, d = (256, 128) if quick else (1024, 256)

    x = jnp.asarray(rng.randn(n, d), jnp.bfloat16)
    t = time_fn(jax.jit(ref.kfac_factor_ref), x)
    err = float(jnp.max(jnp.abs(
        ops.kfac_factor(x, bm=64, bn=64, bk=128, interpret=True)
        - ref.kfac_factor_ref(x))))
    LAST_RESULTS["kernel.kfac_factor_syrk"] = {"us": t, "maxerr": err}
    out.append(row("kernel.kfac_factor_syrk", t, f"maxerr={err:.2e}"))

    nb, b, m = (2, 64, 64) if quick else (4, 128, 128)
    binv = jnp.asarray(rng.randn(nb, b, b), jnp.float32)
    w = jnp.asarray(rng.randn(nb, b, m), jnp.float32)
    t = time_fn(jax.jit(ref.block_precond_ref), binv, w)
    err = float(jnp.max(jnp.abs(
        ops.kfac_block_precond(binv, w, bm=32, bn=32, bk=32, interpret=True)
        - ref.block_precond_ref(binv, w))))
    LAST_RESULTS["kernel.kfac_block_precond"] = {"us": t, "maxerr": err}
    out.append(row("kernel.kfac_block_precond", t, f"maxerr={err:.2e}"))

    bh, s, hd, win = (2, 64, 32, 16) if quick else (4, 128, 64, 32)
    q = jnp.asarray(rng.randn(bh, s, hd), jnp.float32)
    k = jnp.asarray(rng.randn(bh, s, hd), jnp.float32)
    v = jnp.asarray(rng.randn(bh, s, hd), jnp.float32)
    t = time_fn(jax.jit(lambda q, k, v: ref.swa_attention_ref(
        q, k, v, window=win)), q, k, v)
    err = float(jnp.max(jnp.abs(
        ops.swa_attention(q, k, v, window=win, bq=32, bk=32, interpret=True)
        - ref.swa_attention_ref(q, k, v, window=win))))
    LAST_RESULTS["kernel.swa_attention"] = {"us": t, "maxerr": err}
    out.append(row("kernel.swa_attention", t, f"maxerr={err:.2e}"))

    # ---- fp8 pack/unpack: ref-vs-pallas A/B + stale-memory ratio ----
    from repro.core.stale import stat_payload_bytes
    from repro.kernels import dispatch

    nbq, bq = (2, 48) if quick else (4, 96)
    fq = rng.randn(nbq, bq, bq).astype(np.float32)
    fq = jnp.asarray(fq + np.swapaxes(fq, -1, -2))
    pack_ref = jax.jit(lambda f: dispatch.fp8_pack(f, backend="ref"))
    t = time_fn(pack_ref, fq)
    pay_r, sc_r = pack_ref(fq)
    pay_p, sc_p = dispatch.fp8_pack(fq, backend="pallas")
    err = max(float(jnp.max(jnp.abs(pay_r.astype(jnp.float32)
                                    - pay_p.astype(jnp.float32)))),
              float(jnp.max(jnp.abs(sc_r - sc_p))))
    LAST_RESULTS["kernel.fp8_pack"] = {"us": t, "maxerr": err}
    out.append(row("kernel.fp8_pack", t, f"maxerr={err:.2e}"))

    unpack_ref = jax.jit(lambda p, s: dispatch.fp8_unpack(p, s, bq,
                                                          backend="ref"))
    t = time_fn(unpack_ref, pay_r, sc_r)
    err = float(jnp.max(jnp.abs(
        unpack_ref(pay_r, sc_r)
        - dispatch.fp8_unpack(pay_p, sc_p, bq, backend="pallas"))))
    LAST_RESULTS["kernel.fp8_unpack"] = {"us": t, "maxerr": err}
    out.append(row("kernel.fp8_unpack", t, f"maxerr={err:.2e}"))

    # resident/communicated bytes of the fp8 payload vs dense fp32 for one
    # sym-packed factor of this shape (paper §4.3 + §5.2 on top of packing)
    fp8_b = stat_payload_bytes(fq.shape, "fp8_e4m3")
    f32_b = int(np.prod(fq.shape)) * 4
    LAST_RESULTS["stale_memory.fp8_over_fp32"] = {
        "ratio": fp8_b / f32_b, "fp8_bytes": fp8_b, "fp32_dense_bytes": f32_b,
    }
    out.append(row("stale_memory.fp8_over_fp32", 0.0,
                   f"ratio={fp8_b / f32_b:.3f}"))

    # ---- Stage-4 inversion A/B: ref eigh vs Pallas Newton-Schulz ----
    di = _bench_damped_inverse(quick)
    for name, rec in di.items():
        LAST_RESULTS[f"damped_inverse.{name}"] = rec
        extra = (f"maxerr={rec['maxerr']:.2e}" if "maxerr" in rec
                 else f"flops={rec['flops']:.3g}")
        out.append(row(f"damped_inverse.{name}", rec["us"], extra))
    LAST_RESULTS["damped_inverse.ns_over_eigh"] = {
        "us_ratio": di["newton_schulz"]["us"] / di["eigh"]["us"],
        "ns_gemm_flops": di["newton_schulz"]["flops"],
    }
    out.append(row("damped_inverse.ns_over_eigh", 0.0,
                   f"us_ratio={di['newton_schulz']['us'] / di['eigh']['us']:.2f}"))

    # ---- Stage-4 distribution A/B: replicated vs sharded refresh ----
    s4 = _bench_stage4(quick)
    for name, rec in s4.items():
        LAST_RESULTS[name] = rec
        if "us_ratio" in rec:
            extra = f"us_ratio={rec['us_ratio']:.3f}"
        elif "maxerr_vs_replicated" in rec:
            extra = f"maxerr={rec['maxerr_vs_replicated']:.2e}"
        else:
            extra = f"devices={rec['devices']}"
        out.append(row(name, rec.get("us", 0.0), extra))

    # ---- Stage-3 comm strategy A/B: dense vs ring vs ring_fp8 ----
    cm = _bench_comm(quick)
    for name, rec in cm.items():
        LAST_RESULTS[name] = rec
        if "ratio" in rec:
            extra = f"ratio={rec['ratio']:.3f}"
        elif "wire_ratio" in rec:
            extra = (f"wire_ratio={rec['wire_ratio']:.3f} "
                     f"maxerr={rec['maxerr']:.2e}")
        else:
            extra = f"wire_bytes={rec['wire_bytes']}"
        out.append(row(name, rec.get("us", 0.0), extra))

    # ---- attention backward A/B: recompute-through-ref VJP vs fused ----
    ab = _bench_attn_bwd(quick)
    for name, rec in ab.items():
        LAST_RESULTS[f"attn_bwd.{name}"] = rec
        out.append(row(f"attn_bwd.{name}", rec["us"],
                       f"bwd_flops={rec['bwd_flops']:.3g}"))
    ratio = (ab["fused"]["bwd_flops"] / ab["recompute"]["bwd_flops"]
             if ab["recompute"]["bwd_flops"] else float("nan"))
    LAST_RESULTS["attn_bwd.fused_over_recompute"] = {
        "flops_ratio": ratio,
        "us_ratio": ab["fused"]["us"] / ab["recompute"]["us"],
    }
    out.append(row("attn_bwd.fused_over_recompute", 0.0,
                   f"flops_ratio={ratio:.3f}"))

    # ---- serving decode A/B: dense-cache walk vs ring flash decode ----
    sv = _bench_serve(quick)
    for name, rec in sv.items():
        LAST_RESULTS[name] = rec
        if "us_ratio" in rec:
            extra = f"us_ratio={rec['us_ratio']:.3f}"
        elif "ratio" in rec:
            extra = f"ratio={rec['ratio']:.3f}"
        else:
            extra = (f"max_len={rec['max_len']}" if "max_len" in rec
                     else f"window={rec['window']}")
        out.append(row(name, rec.get("us", 0.0), extra))

    # ---- end-to-end dispatch A/B: full train_step per backend ----
    for backend in ("ref", "pallas"):
        t, loss = _bench_train_step(backend, quick)
        LAST_RESULTS[f"train_step.{backend}"] = {"us": t, "loss": loss}
        out.append(row(f"train_step.{backend}", t, f"loss={loss:.4f}"))
    r = LAST_RESULTS["train_step.ref"]["us"]
    p = LAST_RESULTS["train_step.pallas"]["us"]
    LAST_RESULTS["train_step.pallas_over_ref"] = {"ratio": p / r}
    out.append(row("train_step.pallas_over_ref", 0.0, f"ratio={p / r:.2f}"))

    # ---- telemetry cost A/B: metrics stream enabled vs disabled ----
    ob = _bench_obs(quick)
    LAST_RESULTS["obs.loop_disabled"] = {"us": ob["disabled_us"]}
    LAST_RESULTS["obs.loop_enabled"] = {"us": ob["enabled_us"]}
    LAST_RESULTS["obs.enabled_over_disabled"] = {"ratio": ob["ratio"]}
    out.append(row("obs.loop_disabled", ob["disabled_us"],
                   f"steps={ob['steps']}"))
    out.append(row("obs.loop_enabled", ob["enabled_us"],
                   f"steps={ob['steps']}"))
    out.append(row("obs.enabled_over_disabled", 0.0,
                   f"ratio={ob['ratio']:.3f}"))

    # ---- Stage-4 overlap A/B: chunked pipeline vs inline refresh ----
    # LAST in the sequence: this subprocess runs minutes of full train
    # steps, and the rows measured after it would inherit its thermal /
    # memory shadow (observed inflating comm.* by ~40%)
    ov = _bench_overlap(quick)
    for name, rec in ov.items():
        LAST_RESULTS[name] = rec
        if "us_ratio" in rec:
            extra = f"us_ratio={rec['us_ratio']:.3f} chunks={rec['chunks']}"
        elif "chunks" in rec:
            extra = f"chunks={rec['chunks']}"
        else:
            extra = f"devices={rec['devices']}"
        out.append(row(name, rec.get("us", 0.0), extra))
    return out


if __name__ == "__main__":
    import sys
    if "--comm-json" in sys.argv:
        # subprocess entry for _bench_comm: emit the comm A/B dict as the
        # last stdout line (the parent parses it)
        import json
        print(json.dumps(_bench_comm_local(quick="--quick" in sys.argv)))
    elif "--stage4-json" in sys.argv:
        import json
        print(json.dumps(_bench_stage4_local(quick="--quick" in sys.argv)))
    elif "--overlap-json" in sys.argv:
        import json
        print(json.dumps(_bench_overlap_local(quick="--quick" in sys.argv)))
    else:
        for r in run():
            print(r)
