"""Paper Table 2 "reduction" / Fig. 6: communication volume for statistics
under the adaptive-interval scheme (Algorithms 1-2).

Trains the ConvNet with SP-NGD for N steps, letting the IntervalController
schedule refreshes; reports (a) the stale-vs-dense byte reduction rate for
the statistics ReduceScatterV traffic (symmetric-packed bytes), matching
Table 2's "reduction" column, and (b) the per-step byte series (Fig. 6)
written to ``experiments/comm_volume_bs{bs}.csv`` — one row per step with
the storage-ledger bytes plus a wire-bytes column per Stage-3 strategy
(dense / ring / ring_fp8 / hier / fused; ``repro.comm``), and for ``hier``
the per-level (intra-host / inter-host) split under a modelled 2-host x
4-device scatter group. Also reports the same run at two batch sizes — the
paper's observation is that LARGER batches fluctuate less and reduce more.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_convnet, row
from repro.comm import STRATEGIES, make_comm_config
from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController
from repro.data.synthetic import image_batches


# the CSV's wire columns are a topology MODEL, not a measurement: price the
# hier split at the paper-style 2 hosts x 4 devices (scatter group of 8).
# Flat strategies ignore both knobs.
_HIER_DPH = 4
_HIER_GROUP = 8


def _cfg(strategy: str):
    if strategy == "hier":
        return make_comm_config(strategy, devices_per_host=_HIER_DPH)
    return make_comm_config(strategy)


def _run_training(batch_size: int, steps: int, seed: int = 0):
    model, params = make_convnet(widths=(8, 16), blocks=1, seed=seed)
    data = image_batches(10, batch_size, size=16, seed=seed)
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3))
    state = opt.init(params)
    wire = {s: opt.wire_bytes(_cfg(s), group_size=_HIER_GROUP)
            for s in STRATEGIES}
    hier_levels = opt.wire_level_bytes(_cfg("hier"), group_size=_HIER_GROUP)
    ctrl = IntervalController(opt.stat_names(), alpha=0.1,
                              bytes_per_stat=opt.stat_bytes(),
                              wire_bytes_per_stat=wire["dense"])
    step_j = jax.jit(opt.step)
    fast_j = jax.jit(opt.step_fast)
    series = []
    for t in range(1, steps + 1):
        batch = next(data)
        flags = ctrl.flags(t)
        if any(flags.values()):
            jflags = {k: jnp.asarray(v) for k, v in flags.items()}
            params, state, m = step_j(params, state, batch, jflags,
                                      1e-3, 0.05, 0.9)
            sims = {k: (float(m["sims"][k][0]), float(m["sims"][k][1]))
                    for k in m["sims"]}
            ctrl.update(t, flags, sims)
        else:
            params, state, m = fast_j(params, state, batch, 1e-3, 0.05, 0.9)
            ctrl.update(t, flags, {})
        refreshed = [k for k, v in flags.items() if v]
        step_bytes = sum(ctrl.stats[k].bytes_per_refresh for k in refreshed)
        a_bytes = sum(ctrl.stats[k].bytes_per_refresh
                      for k in refreshed if k.endswith(".a"))
        wire_cols = tuple(sum(wire[s][k] for k in refreshed)
                          for s in STRATEGIES)
        wire_cols += (sum(hier_levels[k][0] for k in refreshed),
                      sum(hier_levels[k][1] for k in refreshed))
        series.append((t, step_bytes, a_bytes, wire_cols, float(m["loss"])))
    return ctrl, series


def run(quick: bool = False):
    steps = 30 if quick else 120
    out = []
    os.makedirs("experiments", exist_ok=True)
    # per-refresh wire volume is a property of the stat template, not of
    # the batch size: compute it once, outside the per-bs training loop
    model, _ = make_convnet(widths=(8, 16), blocks=1)
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3))
    wire_totals = {s: sum(opt.wire_bytes(_cfg(s),
                                         group_size=_HIER_GROUP).values())
                   for s in STRATEGIES}
    hier_levels = opt.wire_level_bytes(_cfg("hier"), group_size=_HIER_GROUP)
    hier_intra = sum(v[0] for v in hier_levels.values())
    hier_inter = sum(v[1] for v in hier_levels.values())
    for bs in ([64] if quick else [32, 128]):
        ctrl, series = _run_training(bs, steps)
        s = ctrl.summary()
        out.append(row(f"table2.stale_reduction_bs{bs}", 0.0,
                       f"reduction={100 * s['reduction_rate']:.1f}%"))
        with open(f"experiments/comm_volume_bs{bs}.csv", "w") as f:
            f.write("step,stat_bytes,a_bytes,"
                    + ",".join(f"wire_{s}" for s in STRATEGIES)
                    + ",wire_hier_intra,wire_hier_inter,loss\n")
            for t, b, ab, wc, l in series:
                f.write(f"{t},{b},{ab},"
                        + ",".join(str(w) for w in wc) + f",{l:.4f}\n")
    # per-refresh Stage-3 wire volume per strategy (repro.comm accounting:
    # dense = raw f32 blocked arrays, ring = sym-packed f32 triangles,
    # ring_fp8 = fp8 payload + per-block f32 scales)
    for s in STRATEGIES:
        out.append(row(f"table2.wire_bytes_{s}", 0.0,
                       f"bytes={wire_totals[s]}"))
    out.append(row("table2.wire_fp8_over_f32", 0.0,
                   f"ratio={wire_totals['ring_fp8'] / wire_totals['dense']:.3f}"))
    # hier's level split: the inter-host leg is the scarce resource the
    # two-level reduce protects — report it against the dense f32 wire
    out.append(row("table2.wire_hier_levels", 0.0,
                   f"intra={hier_intra} inter={hier_inter} "
                   f"inter/dense={hier_inter / wire_totals['dense']:.3f}"))
    # symmetric packing saving (paper §5.2): triangular vs full factor bytes
    model, _ = make_convnet(widths=(8, 16), blocks=1)
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig())
    packed = sum(opt.stat_bytes().values())
    full = 0
    for fam, stats in jax.eval_shape(model.fstats).items():
        for k, leaf in stats.items():
            full += int(np.prod(leaf.shape)) * 4
    out.append(row("sec52.sym_packing_saving", 0.0,
                   f"packed/full={packed / full:.3f}"))
    # true payload bytes per factor storage dtype: stat_bytes threads
    # NGDConfig.factor_dtype through the ledger, so the reduce-scatter /
    # stale-memory accounting reflects what would actually move (fp8 =
    # sym-packed payload + per-block f32 scales; repro.quant)
    by_dtype = {}
    for name, fd in (("f32", jnp.float32), ("bf16", jnp.bfloat16),
                     ("fp8", "fp8_e4m3")):
        o = SPNGD(model.loss, model.site_infos(), model.fstats,
                  model.site_counts, NGDConfig(factor_dtype=fd))
        by_dtype[name] = sum(o.stat_bytes().values())
        out.append(row(f"table2.payload_bytes_{name}", 0.0,
                       f"bytes={by_dtype[name]}"))
    out.append(row("table2.payload_fp8_over_f32", 0.0,
                   f"ratio={by_dtype['fp8'] / by_dtype['f32']:.3f}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
