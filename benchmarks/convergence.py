"""Paper Table 1 / Fig. 1 analogue: steps-to-target, SP-NGD vs SGD.

The paper's headline: NGD reaches target accuracy in ~half the steps of SGD
(1,760 vs 3,519 at BS=32K). At container scale we train (a) the ConvNet on
the synthetic image task with the paper's full scheme (running mixup, random
erasing, polynomial decay, coupled momentum, weight rescale) and (b) a tiny
LM, and report steps to reach a target loss for each optimizer with a small
per-optimizer lr sweep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_convnet, row, time_fn
from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController
from repro.data.augment import RunningMixup, random_erase
from repro.data.synthetic import image_batches
from repro.optim.schedules import coupled_momentum, polynomial_decay
from repro.optim.sgd import SGD


def _train_convnet(optimizer: str, lr0: float, steps: int, *, seed: int = 0,
                   use_schemes: bool = True, stale: bool = True):
    model, params = make_convnet(widths=(8, 16), blocks=1, seed=seed)
    data = image_batches(10, 64, size=16, seed=seed)
    mixup = RunningMixup(0.4, 10, seed=seed)
    rng = np.random.RandomState(seed)
    lr_fn = polynomial_decay(lr0, 0, steps, 4.0)
    mom_fn = coupled_momentum(0.9 * lr0 / lr0, lr0)  # m0 = 0.9

    losses = []
    if optimizer == "ngd":
        opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                    model.site_counts,
                    NGDConfig(damping=1e-3, weight_rescale=use_schemes))
        state = opt.init(params)
        ctrl = IntervalController(opt.stat_names(), alpha=0.1)
        step_j = jax.jit(opt.step)
        fast_j = jax.jit(opt.step_fast)
        for t in range(1, steps + 1):
            b = next(data)
            if use_schemes:
                imgs = jnp.asarray(random_erase(rng, np.asarray(b["images"])))
                x, y = mixup(imgs, b["labels"])
            else:
                x, y = b["images"], jax.nn.one_hot(b["labels"], 10)
            batch = {"images": x, "labels": y}
            lr = lr_fn(t - 1)
            mom = 0.9 * lr / lr0
            flags = ctrl.flags(t) if stale else {k: True for k in ctrl.stats}
            if any(flags.values()):
                jflags = {k: jnp.asarray(v) for k, v in flags.items()}
                params, state, m = step_j(params, state, batch, jflags,
                                          1e-3, lr, mom)
                sims = {k: (float(m["sims"][k][0]), float(m["sims"][k][1]))
                        for k in m["sims"]}
                ctrl.update(t, flags, sims)
            else:
                params, state, m = fast_j(params, state, batch, 1e-3, lr, mom)
                ctrl.update(t, flags, {})
            losses.append(float(m["loss"]))
        return losses, ctrl
    else:
        opt = SGD(model.loss)
        state = opt.init(params)
        step_j = jax.jit(opt.step)
        for t in range(1, steps + 1):
            b = next(data)
            if use_schemes:
                x, y = mixup(b["images"], b["labels"])
            else:
                x, y = b["images"], jax.nn.one_hot(b["labels"], 10)
            batch = {"images": x, "labels": y}
            params, state, m = step_j(params, state, batch, lr_fn(t - 1), 0.9)
            losses.append(float(m["loss"]))
        return losses, None


def steps_to(losses, target):
    run = []
    for i, l in enumerate(losses):
        run.append(l)
        if np.mean(run[-5:]) < target and len(run) >= 5:
            return i + 1
    return None


def run(quick: bool = False):
    steps = 40 if quick else 80
    target = 1.6
    out = []
    best_ngd, best_sgd = None, None
    for lr in ([0.05] if quick else [0.02, 0.05, 0.1]):
        losses, _ = _train_convnet("ngd", lr, steps)
        s = steps_to(losses, target)
        if s is not None and (best_ngd is None or s < best_ngd):
            best_ngd = s
    for lr in ([0.1] if quick else [0.05, 0.1, 0.3]):
        losses, _ = _train_convnet("sgd", lr, steps)
        s = steps_to(losses, target)
        if s is not None and (best_sgd is None or s < best_sgd):
            best_sgd = s
    out.append(row("convergence.ngd_steps_to_target", 0.0,
                   f"steps={best_ngd}"))
    out.append(row("convergence.sgd_steps_to_target", 0.0,
                   f"steps={best_sgd}"))
    if best_ngd and best_sgd:
        out.append(row("convergence.ngd_vs_sgd_step_ratio", 0.0,
                       f"ratio={best_ngd / best_sgd:.2f}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
