"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on device)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def make_convnet(widths=(8, 16), blocks=1, bn="unit", seed=0):
    from repro.models.resnet import ConvNet, ConvNetConfig
    cfg = ConvNetConfig(widths=widths, blocks_per_stage=blocks, bn_fisher=bn)
    model = ConvNet(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return model, params


def image_batch(b=64, size=16, seed=0):
    from repro.data.synthetic import image_batches
    return next(image_batches(10, b, size=size, seed=seed))


def make_tiny_lm(arch="llama3_2_1b", seed=0):
    from repro.configs import get_config
    from repro.models.transformer import DecoderLM
    cfg = get_config(arch).reduced()
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return model, params, cfg


def lm_data(cfg, b=8, s=64, seed=0):
    from repro.data.synthetic import token_batches
    it = token_batches(cfg.vocab, b, s, seed=seed)
    return it
