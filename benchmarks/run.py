"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV. Modules:
  convergence      Table 1 / Fig. 1  (NGD vs SGD steps-to-target)
  fisher_ablation  Fig. 5 technique ablation (emp/1mc x unitBN/fullBN x stale)
  stale_reduction  Table 2 reduction % + Fig. 6 byte series
  scaling          Fig. 5 time/step vs #devices (measured + comm model)
  kernels_bench    Pallas kernel contracts + ref-vs-pallas train_step A/B
  serve_bench      continuous-batching decode throughput vs concurrency

The kernels module additionally writes ``BENCH_kernels.json`` (repo root)
with both backends' step timings so later PRs have a perf trajectory to
compare against; serve_bench rows measured in the same invocation are
merged into it.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import traceback

import jax


def _emit_kernels_json(quick: bool) -> None:
    from benchmarks import kernels_bench, serve_bench
    if not kernels_bench.LAST_RESULTS:
        return
    results = dict(kernels_bench.LAST_RESULTS)
    # serve_bench (when it ran in this invocation) shares the snapshot so
    # the bench_compare gate sees serve.* rows; the private _curve blob
    # stays out — it goes to the standalone serve_curve.json artifact
    results.update({k: v for k, v in serve_bench.LAST_RESULTS.items()
                    if not k.startswith("_")})
    rec = {
        "quick": quick,
        "jax_backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "host": platform.machine(),
        "note": ("Pallas kernels run interpret=True on CPU: "
                 "train_step.pallas timings here measure the dispatch "
                 "plumbing, not TPU kernel speed"),
        "results": results,
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (convergence, fisher_ablation, kernels_bench,
                            scaling, serve_bench, stale_reduction)
    modules = {
        "kernels_bench": kernels_bench,
        "serve_bench": serve_bench,
        "fisher_ablation": fisher_ablation,
        "stale_reduction": stale_reduction,
        "scaling": scaling,
        "convergence": convergence,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules.items():
        try:
            for r in mod.run(quick=args.quick):
                print(r, flush=True)
        except Exception as e:
            failed.append(name)
            print(f"{name}.ERROR,0.0,{type(e).__name__}", flush=True)
            traceback.print_exc(file=sys.stderr)
            continue
    # after the loop so a same-invocation serve_bench run lands in the
    # snapshot too (results merge in _emit_kernels_json)
    if "kernels_bench" in modules and "kernels_bench" not in failed:
        try:
            _emit_kernels_json(args.quick)
        except OSError as e:
            # read-only checkout etc.: the benchmark itself succeeded
            print(f"# BENCH_kernels.json not written: {e}",
                  file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
