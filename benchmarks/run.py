"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV. Modules:
  convergence      Table 1 / Fig. 1  (NGD vs SGD steps-to-target)
  fisher_ablation  Fig. 5 technique ablation (emp/1mc x unitBN/fullBN x stale)
  stale_reduction  Table 2 reduction % + Fig. 6 byte series
  scaling          Fig. 5 time/step vs #devices (measured + comm model)
  kernels_bench    Pallas kernel contracts
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (convergence, fisher_ablation, kernels_bench,
                            scaling, stale_reduction)
    modules = {
        "kernels_bench": kernels_bench,
        "fisher_ablation": fisher_ablation,
        "stale_reduction": stale_reduction,
        "scaling": scaling,
        "convergence": convergence,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules.items():
        try:
            for r in mod.run(quick=args.quick):
                print(r, flush=True)
        except Exception as e:
            failed.append(name)
            print(f"{name}.ERROR,0.0,{type(e).__name__}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
