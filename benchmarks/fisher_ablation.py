"""Paper Fig. 5 ablation: per-step cost of the practical-NGD techniques.

Measures wall time per training step on the ConvNet for:
  sgd                 first-order reference
  1mc + fullBN        the naive NGD baseline (extra backward + 2Cx2C BN)
  1mc + unitBN
  emp + fullBN
  emp + unitBN        the paper's practical estimator set
  emp + unitBN, no-refresh step ("stale" steady state: Algorithm 1's fast
                      path — the cost the paper drives NGD down to)

Derived column reports the overhead ratio vs SGD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import image_batch, make_convnet, row, time_fn
from repro.core.ngd import NGDConfig, SPNGD
from repro.optim.sgd import SGD


def run(quick: bool = False):
    batch = image_batch(b=32 if quick else 128, size=16)
    out = []
    model, params = make_convnet(widths=(8, 16), blocks=1)

    sgd = SGD(model.loss)
    sgd_state = sgd.init(params)
    t_sgd = time_fn(jax.jit(sgd.step), params, sgd_state, batch, 0.1, 0.9)
    out.append(row("fig5.sgd_step", t_sgd, "x1.00"))

    variants = [("emp", "unit"), ("emp", "full"),
                ("1mc", "unit"), ("1mc", "full")]
    t_emp_unit = None
    for est, bn in variants:
        model_v, params_v = make_convnet(widths=(8, 16), blocks=1, bn=bn)
        opt = SPNGD(model_v.loss, model_v.site_infos(), model_v.fstats,
                    model_v.site_counts,
                    NGDConfig(damping=1e-3, estimator=est))
        state = opt.init(params_v)
        flags = {k: jnp.asarray(True) for k in opt.stat_names()}
        if est == "1mc":
            fn = jax.jit(lambda p, s, b: opt.step(
                p, s, b, flags, 1e-3, 0.05, 0.9,
                rng=jax.random.PRNGKey(0)))
        else:
            fn = jax.jit(lambda p, s, b: opt.step(p, s, b, flags,
                                                  1e-3, 0.05, 0.9))
        t = time_fn(fn, params_v, state, batch)
        out.append(row(f"fig5.{est}_{bn}BN_step", t, f"x{t / t_sgd:.2f}"))
        if (est, bn) == ("emp", "unit"):
            t_emp_unit = t
            state_ref = state
            opt_ref = opt
            params_ref = params_v

    # stale steady state: no statistic refresh (Algorithm 1 fast path)
    fastfn = jax.jit(lambda p, s, b: opt_ref.step_fast(p, s, b, 1e-3, 0.05,
                                                       0.9))
    t_fast = time_fn(fastfn, params_ref, state_ref, batch)
    out.append(row("fig5.emp_unitBN_stale_step", t_fast,
                   f"x{t_fast / t_sgd:.2f}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
