"""Serving throughput bench: continuous batching over the flash-decode path.

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick] \
        [--json-out experiments/serve_curve.json]

Drives ``repro.serve.ContinuousBatcher`` (fp8 ring cache + ``swa_decode``)
over a queue of variable-length requests at increasing concurrency (slot
counts) and reports the tokens/sec vs tokens/sec/user curve — the serving
trade the paper's "heavy traffic" motivation cares about: aggregate
throughput grows with slots while per-user latency degrades, and the curve
shows where. Also records fp8-vs-f32 cache footprints.

Rows land in ``LAST_RESULTS`` (merged into ``BENCH_kernels.json`` by
``benchmarks.run``); ``__main__ --json-out`` additionally writes the raw
curve as standalone JSON for the CI artifact. Timings are CPU wall clock of
the jitted ref-backend decode loop (repo convention: interpret-mode Pallas
wall time is Python emulation, so jnp is the reported column); the curve's
SHAPE — throughput scaling across slot counts on identical work — is the
durable signal, not the absolute tok/s.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row

# filled by run(): {"serve.batch_c<k>": {...}, "serve.cache_bytes": {...}}
LAST_RESULTS: dict = {}


def _build(window: int, backend: str = "ref"):
    from repro.configs import get_config
    from repro.models.transformer import DecoderLM
    from repro.serve import ServeConfig

    cfg = get_config("llama3_2_1b").reduced(
        head_dim=32, d_ff=128, vocab=256, sliding_window=window)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    serve = ServeConfig(kv_cache="ring", kv_dtype="fp8_e4m3",
                        backend=backend)
    return model, params, serve, cfg


def _requests(n: int, vocab: int, max_new: int, seed: int = 0):
    from repro.serve import Request
    rng = np.random.RandomState(seed)
    # variable prompt lengths exercise per-length prefill + slot reuse
    lens = rng.randint(4, 17, n)
    return [Request(prompt=rng.randint(0, vocab, (lens[i],)),
                    max_new=max_new, uid=i) for i in range(n)]


def run(quick: bool = False):
    from repro.serve import ContinuousBatcher, cache_bytes
    out = []
    LAST_RESULTS.clear()
    window = 32
    max_len, max_new = (128, 24) if quick else (256, 48)
    concurrency = (1, 2, 4) if quick else (1, 2, 4, 8)
    n_req = {c: 2 * c for c in concurrency}
    model, params, serve, cfg = _build(window)

    curve = []
    for c in concurrency:
        batcher = ContinuousBatcher(model, params, serve, slots=c,
                                    max_len=max_len)
        # warm-up request pays the prefill/step jit (per-batcher: the jitted
        # closures are per-instance) so the timed queue is steady state;
        # prompt lengths are re-drawn below, so prefill still jits once per
        # NEW length inside the timed region — that is the admission cost a
        # non-bucketing server actually pays, and it is identical across
        # slot counts, so the curve shape stays comparable
        for r in _requests(1, cfg.vocab, 2, seed=99):
            batcher.run([r])
        reqs = _requests(n_req[c], cfg.vocab, max_new, seed=c)
        t0 = time.perf_counter()
        results = batcher.run(reqs)
        dt = time.perf_counter() - t0
        total = sum(len(v) for v in results.values())
        assert len(results) == n_req[c] and total == n_req[c] * max_new
        tok_s = total / dt
        rec = {"us": dt * 1e6 / total,          # wall us per generated token
               "slots": c, "requests": n_req[c], "tokens": total,
               "tok_s": tok_s, "tok_s_per_user": tok_s / c}
        LAST_RESULTS[f"serve.batch_c{c}"] = rec
        out.append(row(f"serve.batch_c{c}", rec["us"],
                       f"tok_s={tok_s:.1f} per_user={tok_s / c:.1f}"))
        curve.append(rec)

    from repro.serve import ServeConfig
    fp8 = cache_bytes(model.init_cache(max(concurrency), max_len,
                                       serve=serve))
    f32 = cache_bytes(model.init_cache(
        max(concurrency), max_len,
        serve=ServeConfig(kv_cache="ring", kv_dtype="f32")))
    dense = cache_bytes(model.init_cache(max(concurrency), max_len))
    LAST_RESULTS["serve.cache_bytes"] = {
        "fp8_ring_bytes": fp8, "f32_ring_bytes": f32,
        "f32_dense_bytes": dense, "ratio": fp8 / f32,
        "slots": max(concurrency), "max_len": max_len, "window": window,
    }
    out.append(row("serve.cache_bytes", 0.0,
                   f"fp8={fp8} f32_ring={f32} ratio={fp8 / f32:.3f}"))
    LAST_RESULTS["_curve"] = {
        "window": window, "max_len": max_len, "max_new": max_new,
        "points": curve,
    }
    return out


def _write_json(path: str) -> None:
    import json
    import os
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    rec = {"jax_backend": jax.default_backend(),
           "results": {k: v for k, v in LAST_RESULTS.items()
                       if not k.startswith("_")},
           "curve": LAST_RESULTS.get("_curve", {})}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json-out", default=None,
                    help="also write the raw concurrency curve as JSON "
                         "(the CI artifact)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(quick=args.quick):
        print(r, flush=True)
    if args.json_out:
        _write_json(args.json_out)
        print(f"# wrote {args.json_out}")
