"""``hypothesis`` when installed, else a tiny deterministic fallback.

The property tests only need ``given`` + ``settings`` + two strategies
(``integers``, ``sampled_from``). On a bare environment (no hypothesis) this
shim samples a small, seeded set of examples instead of skipping the tests
outright — less shrinking power, same coverage intent. Import as::

    from hypothesis_compat import given, settings, st
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False

    # keep the bare-env sweep small: every distinct shape re-traces the jitted
    # kernels, so example count dominates the suite's wall time
    _MAX_FALLBACK_EXAMPLES = 4

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    st = _Strategies()

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # NOTE: no functools.wraps — copying __wrapped__ would expose the
            # strategy parameters as the signature and pytest would look for
            # fixtures named after them
            def wrapper():
                n = min(getattr(wrapper, "_max_examples", 10),
                        _MAX_FALLBACK_EXAMPLES)
                rng = random.Random(1234)
                for _ in range(n):
                    draw = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(**draw)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
