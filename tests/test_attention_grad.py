"""Fused Pallas backward for sliding-window attention.

Three layers of coverage:

* op level — ``dispatch.swa_attention_fwd_res`` / ``swa_attention_bwd``
  parity between the ref (jax.vjp of the ref forward) and pallas (fused
  dq/dk/dv kernels, interpret mode on CPU) backends in the GQA kernel
  layout, including odd/padded sequence lengths and bf16 inputs.
* model level — ``models.attention`` gradients, ref vs pallas route, over
  the shape grid the ISSUE pins: odd/padded S, window ∈ {0, S/4}, GQA
  ratios {1, 4}, bf16; plus a spy asserting the pallas VJP calls only the
  fused backward ops (zero recompute-through-ref attention passes).
* e2e — a 20-step SP-NGD train-loss parity run (reusing
  ``test_backend_dispatch``'s fixture) on reduced mixtral — sliding-window
  + MoE + GQA — driven through the new custom VJP.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, ops, ref
from repro.models.attention import attention


def _gqa_qkv(seed, bkv, g, s, hd, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(bkv, g, s, hd), dtype)
    k = jnp.asarray(rng.randn(bkv, s, hd), dtype)
    v = jnp.asarray(rng.randn(bkv, s, hd), dtype)
    return q, k, v


def _tols(dtype):
    # f32 carries the ISSUE's 1e-3 contract with lots of margin; bf16
    # outputs/cotangents quantize at ~2^-8 so parity is ulp-bounded
    return (1e-3, 1e-3) if dtype == jnp.float32 else (0.05, 0.05)


# ---------------------------------------------------------------------------
# op level: fwd_res + bwd, ref vs pallas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,window", [(64, 16), (50, 13), (33, 0), (33, 8)])
@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_res_op_parity(s, window, g, dtype):
    q, k, v = _gqa_qkv(s + window + g, 2, g, s, 16, dtype)
    o_r, lse_r = dispatch.swa_attention_fwd_res(q, k, v, window=window,
                                                backend="ref")
    o_p, lse_p = dispatch.swa_attention_fwd_res(q, k, v, window=window,
                                                backend="pallas")
    assert o_p.shape == q.shape and o_p.dtype == q.dtype
    assert lse_p.shape == q.shape[:-1] and lse_p.dtype == jnp.float32
    rtol, atol = _tols(dtype)
    np.testing.assert_allclose(np.asarray(o_r, np.float32),
                               np.asarray(o_p, np.float32),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(lse_r, lse_p, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("s,window", [(64, 16), (50, 13), (33, 0)])
@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bwd_op_parity(s, window, g, dtype):
    q, k, v = _gqa_qkv(2 * s + window + g, 2, g, s, 16, dtype)
    o, lse = dispatch.swa_attention_fwd_res(q, k, v, window=window,
                                            backend="pallas")
    rng = np.random.RandomState(1)
    do = jnp.asarray(rng.randn(*o.shape), dtype)
    grads_r = dispatch.swa_attention_bwd(q, k, v, o, lse, do, window=window,
                                         backend="ref")
    grads_p = dispatch.swa_attention_bwd(q, k, v, o, lse, do, window=window,
                                         backend="pallas")
    rtol, atol = _tols(dtype)
    for name, a, b in zip(("dq", "dk", "dv"), grads_r, grads_p):
        assert b.dtype == jnp.float32, name
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol, err_msg=name)


@pytest.mark.parametrize("s", [48, 50])
def test_bwd_kernel_against_autodiff_oracle(s):
    """The fused kernels must match jax.grad through the materialized-scores
    oracle (not just the ref op) — guards the lse/delta algebra. s=50 with
    16x16 tiles forces the lcm-padding branch (padded Q rows / K columns)
    in both ops wrappers."""
    q, k, v = _gqa_qkv(11, 2, 2, s, 16)
    w = 12

    def loss(q, k, v):
        out, _ = ref.swa_attention_fwd_res_ref(q, k, v, window=w)
        return jnp.sum(out ** 2)

    dq_o, dk_o, dv_o = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    o, lse = ops.swa_attention_fwd_res(q, k, v, window=w, bq=16, bk=16,
                                       interpret=True)
    dq, dk, dv = ops.swa_attention_bwd(q, k, v, o, lse, 2.0 * o, window=w,
                                       bq=16, bk=16, interpret=True)
    np.testing.assert_allclose(dq, dq_o, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dk, dk_o, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dv, dv_o, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# model level: attention() gradients across the shape grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [33, 50, 64])
@pytest.mark.parametrize("win_frac", [0, 4])
@pytest.mark.parametrize("ratio", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_model_grad_parity_grid(s, win_frac, ratio, dtype):
    window = s // win_frac if win_frac else 0
    b, kv, hd = 2, 2, 16
    h = kv * ratio
    rng = np.random.RandomState(s * 7 + window + ratio)
    q = jnp.asarray(rng.randn(b, s, h, hd), dtype)
    k = jnp.asarray(rng.randn(b, s, kv, hd), dtype)
    v = jnp.asarray(rng.randn(b, s, kv, hd), dtype)

    def f(be):
        return lambda q, k, v: jnp.sum(
            attention(q, k, v, window=window, backend=be).astype(
                jnp.float32) ** 2)

    o_ref = attention(q, k, v, window=window, backend="ref")
    o_pl = attention(q, k, v, window=window, backend="pallas")
    rtol, atol = _tols(dtype)
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pl, np.float32),
                               rtol=rtol, atol=atol)
    g_ref = jax.grad(f("ref"), argnums=(0, 1, 2))(q, k, v)
    g_pl = jax.grad(f("pallas"), argnums=(0, 1, 2))(q, k, v)
    for name, a, b_ in zip(("dq", "dk", "dv"), g_ref, g_pl):
        assert b_.dtype == dtype, name
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=rtol, atol=atol, err_msg=name)


def test_pallas_vjp_is_fused_no_ref_recompute(monkeypatch):
    """backend="pallas" training must take ZERO recompute-through-ref
    attention passes: the custom VJP may touch only the fwd_res/bwd ops."""
    calls = []
    orig = dispatch.lookup

    def spy(op, backend):
        calls.append((op, backend))
        return orig(op, backend)

    monkeypatch.setattr(dispatch, "lookup", spy)
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 32, 4, 8), jnp.float32)
    k = jnp.asarray(rng.randn(1, 32, 2, 8), jnp.float32)
    v = jnp.asarray(rng.randn(1, 32, 2, 8), jnp.float32)
    jax.grad(lambda q: jnp.sum(
        attention(q, k, v, window=8, backend="pallas") ** 2))(q)
    ops_hit = {op for op, _ in calls}
    assert "swa_attention_fwd_res" in ops_hit
    assert "swa_attention_bwd" in ops_hit
    # the plain forward op (the old recompute target) must not be touched
    assert "swa_attention" not in ops_hit


# ---------------------------------------------------------------------------
# e2e: 20-step SP-NGD train-loss parity through the fused backward
# ---------------------------------------------------------------------------

def test_train_20_steps_fused_bwd_matches_ref_moe_swa():
    """Mirror of test_backend_dispatch's e2e (which covers reduced GQA
    llama), on reduced mixtral instead: sliding-window attention + MoE +
    GQA all routed through the fused backward."""
    from test_backend_dispatch import _losses_jit
    l_ref = _losses_jit("ref", arch="mixtral_8x22b")
    l_pl = _losses_jit("pallas", arch="mixtral_8x22b")
    assert np.isfinite(l_pl).all()
    assert l_pl[-1] < l_pl[0]
    # the fused backward is not bit-identical to ref (different reduction
    # order) and this overfit fixture is chaotic past ~step 8; a wrong
    # gradient breaks the prefix immediately (see test_backend_dispatch)
    np.testing.assert_allclose(l_ref[:8], l_pl[:8], rtol=1e-3, atol=1e-3)
    # mixtral's chaotic tail bounces higher than llama's (MoE aux loss);
    # "stays trained" means well below the ~6.3 starting loss
    assert max(l_ref[8:]) < 2.0 and max(l_pl[8:]) < 2.0
