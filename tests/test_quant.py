"""fp8 factor-history subsystem (repro.quant + fp8_pack/fp8_unpack kernels).

Covers the ISSUE-3 acceptance criteria:
  * sym_pack/sym_unpack round-trip identity (property, odd/degenerate b);
  * fp8 encode/decode bounded error <= 2^-2 * per-block amax (both formats,
    both scale modes — actual bound is ~amax/28 for e4m3, ~amax/14 for e5m2);
  * ref-vs-pallas bit parity for the pack/unpack dispatch ops;
  * with factor_dtype="fp8_e4m3": history bytes <= 0.27x fp32 dense,
    Algorithm 2 schedule matches the fp32 run, and a 20-step e2e run stays
    within 2e-2 relative loss of the fp32-history baseline on ref AND pallas.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import kfac
from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController, stat_payload_bytes
from repro.kernels import dispatch
from repro import quant

from test_ngd_optimizer import (loss_fn, fstats_fn, counts_fn, INFOS, _data,
                                D_IN, D_H, D_OUT)


def _sym_blocked(rng, nb, b, lead=()):
    x = rng.randn(*lead, nb, b, b).astype(np.float32)
    return jnp.asarray(x + np.swapaxes(x, -1, -2))


# ---------------------------------------------------------------------------
# sym_pack / sym_unpack (property: round-trip identity, any block size)
# ---------------------------------------------------------------------------

@settings(deadline=None)
@given(nb=st.integers(1, 3), b=st.integers(1, 33))
def test_sym_pack_roundtrip_property(nb, b):
    rng = np.random.RandomState(nb * 100 + b)
    f = _sym_blocked(rng, nb, b)
    p = kfac.sym_pack(f)
    assert p.shape == (nb, b * (b + 1) // 2)
    np.testing.assert_array_equal(kfac.sym_unpack(p, b), f)


def test_sym_unpack_preserves_dtype():
    p = jnp.asarray(np.arange(6), jnp.float8_e4m3fn)   # b=3 packed row
    f = kfac.sym_unpack(p, 3)
    assert f.dtype == jnp.float8_e4m3fn
    np.testing.assert_array_equal(np.asarray(f), np.asarray(f).T)


_PACK_DTYPES = ["float32", "bfloat16", "float8_e4m3fn", "float8_e5m2"]


@settings(deadline=None)
@given(b=st.integers(1, 33), nb=st.integers(1, 3), n_lead=st.integers(0, 2),
       dtype=st.sampled_from(_PACK_DTYPES))
def test_sym_pack_of_unpack_is_identity_property(b, nb, n_lead, dtype):
    """The OTHER round-trip direction: ``sym_pack(sym_unpack(p)) == p``
    bit-for-bit for ARBITRARY payload rows — sym_unpack was rewritten
    scatter -> static gather in the fp8 PR with no property coverage, and
    this is the direction the fp8 history codec actually leans on (stored
    payload -> dense -> payload must not smear bits, for any payload dtype
    incl. the fp8 wire formats)."""
    dt = jnp.dtype(dtype)
    t = b * (b + 1) // 2
    lead = (2,) * n_lead
    rng = np.random.RandomState(b * 101 + nb * 7 + n_lead + len(dtype))
    # random BITS, not random values: exercises every payload bit pattern
    # (incl. NaN/inf encodings) through the gather round-trip
    bits = rng.randint(0, 256, size=lead + (nb, t * dt.itemsize),
                       dtype=np.uint8)
    p = jnp.asarray(bits).view(dt)
    f = kfac.sym_unpack(p, b)
    assert f.shape == lead + (nb, b, b) and f.dtype == dt
    rt = kfac.sym_pack(f)
    assert rt.dtype == dt
    np.testing.assert_array_equal(np.asarray(rt).view(np.uint8),
                                  np.asarray(p).view(np.uint8))
    # unpack output is exactly symmetric at the bit level
    fb = np.asarray(f).view(np.uint8).reshape(lead + (nb, b, b, dt.itemsize))
    np.testing.assert_array_equal(fb, np.swapaxes(fb, -2, -3))


@settings(deadline=None)
@given(b=st.integers(1, 24), dtype=st.sampled_from(_PACK_DTYPES))
def test_sym_unpack_of_pack_is_identity_property(b, dtype):
    """Round-trip from the dense side for every payload dtype (the existing
    f32 property, widened): symmetric dense -> packed -> dense is the
    identity bit-for-bit."""
    dt = jnp.dtype(dtype)
    rng = np.random.RandomState(b + len(dtype))
    f = np.triu(rng.randn(2, b, b))
    f = jnp.asarray(f + np.swapaxes(np.triu(np.asarray(f), 1), -1, -2)
                    ).astype(dt)
    rt = kfac.sym_unpack(kfac.sym_pack(f), b)
    np.testing.assert_array_equal(np.asarray(rt).view(np.uint8),
                                  np.asarray(f).view(np.uint8))


# ---------------------------------------------------------------------------
# fp8 encode/decode: bounded error, both formats/scale modes, degenerates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["e4m3", "e5m2"])
@pytest.mark.parametrize("scale_mode", ["fp32", "pow2"])
def test_fp8_roundtrip_bounded_error(fmt, scale_mode):
    rng = np.random.RandomState(0)
    f = _sym_blocked(rng, 3, 17, lead=(2,)) * 37.0
    enc = quant.encode_stat(f, fmt, scale_mode=scale_mode, backend="ref")
    dec = np.asarray(quant.decode_stat(enc, f.shape, backend="ref"))
    amax = np.max(np.abs(np.asarray(f)), axis=(-1, -2))
    err = np.max(np.abs(dec - np.asarray(f)), axis=(-1, -2))
    assert (err <= 0.25 * amax).all(), (fmt, scale_mode, err / amax)
    assert np.isfinite(dec).all()
    # decoded blocks stay exactly symmetric (packed storage mirrors)
    np.testing.assert_array_equal(dec, np.swapaxes(dec, -1, -2))


@settings(deadline=None)
@given(b=st.integers(1, 21), scale=st.sampled_from([1e-4, 1.0, 3e3]))
def test_fp8_pack_property(b, scale):
    rng = np.random.RandomState(b)
    f = _sym_blocked(rng, 2, b) * scale
    pay, sc = dispatch.fp8_pack(f, backend="ref")
    dec = np.asarray(dispatch.fp8_unpack(pay, sc, b, backend="ref"))
    amax = np.max(np.abs(np.asarray(f)), axis=(-1, -2))
    err = np.max(np.abs(dec - np.asarray(f)), axis=(-1, -2))
    assert (err <= 0.25 * np.maximum(amax, 1e-30)).all()


def test_fp8_zero_blocks_decode_exactly():
    z = jnp.zeros((2, 5, 5))
    enc = quant.encode_stat(z, "e4m3")
    np.testing.assert_array_equal(np.asarray(enc["scale"]), 1.0)
    np.testing.assert_array_equal(quant.decode_stat(enc, z.shape),
                                  np.zeros((2, 5, 5), np.float32))


def test_fp8_rows_nonsquare_stats():
    """Diag/unit-wise stats quantize over the last axis, one scale per row."""
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(4, 3) * 100, jnp.float32)
    enc = quant.encode_stat(x, "e4m3", symmetric=False)
    assert enc["payload"].shape == (4, 3) and enc["scale"].shape == (4,)
    dec = np.asarray(quant.decode_stat(enc, x.shape, symmetric=False))
    amax = np.max(np.abs(np.asarray(x)), -1, keepdims=True)
    assert (np.abs(dec - np.asarray(x)) <= 0.25 * amax).all()


def test_fp8_e5m2_survives_wide_dynamic_range():
    """e5m2 trades mantissa for exponent: a value 2^-20 below its block amax
    still decodes nonzero, where e4m3's narrower span flushes it to zero —
    the per-statistic format choice documented in the README."""
    x = jnp.asarray([[1.0, 2.0 ** -20]], jnp.float32)
    d5 = quant.decode_stat(quant.encode_stat(x, "e5m2", symmetric=False),
                           x.shape, symmetric=False)
    d4 = quant.decode_stat(quant.encode_stat(x, "e4m3", symmetric=False),
                           x.shape, symmetric=False)
    assert float(d5[0, 1]) > 0.0
    assert float(d4[0, 1]) == 0.0


# ---------------------------------------------------------------------------
# ref vs pallas parity (bit-identical payload/scale; interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb,b,lead", [(1, 8, ()), (3, 33, ()), (2, 16, (2,))])
def test_fp8_pack_unpack_ref_vs_pallas(nb, b, lead):
    rng = np.random.RandomState(nb * 10 + b)
    f = _sym_blocked(rng, nb, b, lead=lead)
    pay_r, sc_r = jax.jit(
        lambda f: dispatch.fp8_pack(f, backend="ref"))(f)
    pay_p, sc_p = dispatch.fp8_pack(f, backend="pallas")
    np.testing.assert_array_equal(np.asarray(pay_r).view(np.uint8),
                                  np.asarray(pay_p).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(sc_r), np.asarray(sc_p))
    out_r = jax.jit(
        lambda p, s: dispatch.fp8_unpack(p, s, b, backend="ref"))(pay_r, sc_r)
    out_p = dispatch.fp8_unpack(pay_p, sc_p, b, backend="pallas")
    np.testing.assert_array_equal(np.asarray(out_r), np.asarray(out_p))


# ---------------------------------------------------------------------------
# optimizer integration: bytes, schedule, e2e loss (acceptance criteria)
# ---------------------------------------------------------------------------

def _run_mlp(cfg, steps=20):
    rng = np.random.RandomState(7)
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, D_OUT) * 0.4, jnp.float32)}
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn, cfg)
    state = opt.init(params)
    ctrl = IntervalController(opt.stat_names(), alpha=0.1,
                              bytes_per_stat=opt.stat_bytes())
    step_j = jax.jit(opt.step)
    fast_j = jax.jit(opt.step_fast)
    losses, schedule = [], []
    for t in range(1, steps + 1):
        batch = _data(seed=t)
        flags = ctrl.flags(t)
        schedule.append(tuple(sorted(k for k, v in flags.items() if v)))
        if any(flags.values()):
            jf = {k: jnp.asarray(v) for k, v in flags.items()}
            params, state, m = step_j(params, state, batch, jf, 1e-3, 0.1, 0.9)
            ctrl.update(t, flags, {k: (float(v[0]), float(v[1]))
                                   for k, v in m["sims"].items()})
        else:
            params, state, m = fast_j(params, state, batch, 1e-3, 0.1, 0.9)
            ctrl.update(t, flags, {})
        losses.append(float(m["loss"]))
    return losses, schedule, state


def _history_nbytes(state):
    return sum(sum(x.nbytes for x in jax.tree.leaves(c[part]))
               for c in state["curv"].values() for part in ("prev", "prev2"))


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_fp8_history_e2e_matches_f32(backend):
    l32, s32, st32 = _run_mlp(NGDConfig(damping=1e-3, backend=backend))
    l8, s8, st8 = _run_mlp(NGDConfig(damping=1e-3, factor_dtype="fp8_e4m3",
                                     backend=backend))
    # Algorithm 2 interval schedule must match the fp32 run step for step
    assert s8 == s32
    for a, b in zip(l8, l32):
        assert abs(a - b) <= 2e-2 * abs(b), (a, b)
    # factor-history bytes <= 0.27x the fp32 dense history
    assert _history_nbytes(st8) <= 0.27 * _history_nbytes(st32)


def test_fp8_mixed_flags_precondition_from_dequantized_history():
    """When one stat refreshes and its sibling doesn't, the recomputed
    inverse must consume the DEQUANTIZED history for the stale side — the
    dequantize-on-read contract, exercised explicitly."""
    batch = _data(0)
    rng = np.random.RandomState(3)
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, D_OUT) * 0.4, jnp.float32)}
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn,
                NGDConfig(damping=1e-3, factor_dtype="fp8_e4m3"))
    state = opt.init(params)
    on = {k: jnp.asarray(True) for k in opt.stat_names()}
    params, state, _ = jax.jit(opt.step)(params, state, batch, on,
                                         1e-3, 0.1, 0.9)
    # refresh only l1.a: l1.g's side of the inverse must come from history
    mixed = dict(on)
    mixed["l1.g"] = jnp.asarray(False)
    batch2 = _data(1)
    _, state2, _ = jax.jit(opt.step)(params, state, batch2, mixed,
                                     1e-3, 0.1, 0.9)
    g_hist = quant.decode_stat(
        state["curv"]["l1"]["prev"]["g"],
        jax.eval_shape(fstats_fn)["l1"]["g"].shape)
    # the stored payload for the unrefreshed stat is bit-identical...
    np.testing.assert_array_equal(
        np.asarray(state2["curv"]["l1"]["prev"]["g"]["payload"]).view(np.uint8),
        np.asarray(state["curv"]["l1"]["prev"]["g"]["payload"]).view(np.uint8))
    # ...and the recomputed preconditioner changed (fresh a + stale g)
    assert not np.array_equal(state2["curv"]["l1"]["precond"]["g"],
                              state["curv"]["l1"]["precond"]["g"])
    assert np.isfinite(np.asarray(g_hist)).all()


def test_stat_payload_bytes_accounting():
    # full factor (2, 8, 8): fp32 packed 2*36*4; fp8 packed 2*36*1 + 2*4
    assert stat_payload_bytes((2, 8, 8), jnp.float32) == 2 * 36 * 4
    assert stat_payload_bytes((2, 8, 8), jnp.bfloat16) == 2 * 36 * 2
    assert stat_payload_bytes((2, 8, 8), "fp8_e4m3") == 2 * 36 + 2 * 4
    # non-square: dense elements (+ per-row scale for fp8)
    assert stat_payload_bytes((3, 5), jnp.float32) == 15 * 4
    assert stat_payload_bytes((3, 5), "fp8_e4m3") == 15 + 3 * 4
    # square-but-not-symmetric opt-out
    assert stat_payload_bytes((4, 4), jnp.float32, symmetric=False) == 16 * 4


def test_stat_bytes_follows_factor_dtype():
    opt32 = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn, NGDConfig())
    opt8 = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn,
                 NGDConfig(factor_dtype="fp8_e4m3"))
    b32, b8 = opt32.stat_bytes(), opt8.stat_bytes()
    assert set(b32) == set(b8)
    assert sum(b8.values()) < 0.3 * sum(b32.values())
    # explicit override keeps the old fixed-size accounting
    assert opt8.stat_bytes(dtype_bytes=4) == b32
