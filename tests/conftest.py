"""Give the test process 8 virtual CPU devices (for the distributed-schedule
and collective-analyzer tests) BEFORE jax initializes. Everything else runs
unchanged on device 0. The 512-device setting stays exclusive to
repro.launch.dryrun, per the launcher contract.

Also registers hypothesis profiles when hypothesis is installed. The
property tests deliberately do NOT pin max_examples in their @settings
(a per-test pin would override the profile and make the nightly sweep a
no-op); the profile is the single knob:
  * "ci" (default)  — 12 examples/test: shape diversity without re-tracing
    the jitted kernels dozens of times per property
  * "nightly"       — 200 examples/test, loaded by the scheduled CI job
    via HYPOTHESIS_PROFILE=nightly
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", deadline=None, max_examples=12)
    _hyp_settings.register_profile("nightly", deadline=None,
                                   max_examples=200)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ModuleNotFoundError:
    pass  # bare env: tests/hypothesis_compat.py provides the fallback
