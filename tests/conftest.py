"""Give the test process 8 virtual CPU devices (for the distributed-schedule
and collective-analyzer tests) BEFORE jax initializes. Everything else runs
unchanged on device 0. The 512-device setting stays exclusive to
repro.launch.dryrun, per the launcher contract."""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
