"""Serving-path tests: flash-decode kernel parity, ring-buffer KV cache
semantics, fp8 payload round-trips, continuous batching, and the decode
bugfixes (dense-span clamp, window/q_offset contract).

The pinned contract (see models/attention.attention and
kernels/ref.swa_decode_slot_positions):

* ``window == 0`` always means FULL CAUSAL; ``window=None`` exists only at
  the model/ServeConfig layer and means "inherit the config".
* a decode query at position ``pos`` (== cache length before its own token)
  sees exactly ``min(pos + 1, window)`` keys, its own included.
* ring cache: capacity C == window, token at position p lives in slot
  ``p % C``; the slot the next token will overwrite holds the key that
  falls out of the window on that step.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import dispatch, ref
from repro.models.transformer import DecoderLM
from repro.serve import ContinuousBatcher, Request, ServeConfig, cache_bytes


def _cfg(n_kv_heads=1, window=0, backend="ref"):
    cfg = get_config("llama3_2_1b").reduced()
    return dataclasses.replace(cfg, n_kv_heads=n_kv_heads,
                               sliding_window=window, backend=backend)


@functools.lru_cache(maxsize=None)
def _model(n_kv_heads=1, window=0, backend="ref"):
    cfg = _cfg(n_kv_heads, window, backend)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _tokens(b, t, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, vocab, (b, t)), jnp.int32)


def _decode_fn(model, serve):
    """Jitted single decode step (compile once per config, not per step)."""
    return jax.jit(functools.partial(model.decode_step, serve=serve))


def _teacher_forced_decode(model, params, toks, s, serve):
    """Prefill toks[:, :s], then teacher-force the rest one decode step at a
    time; returns per-position logits (B, T, V) aligned with forward()."""
    t = toks.shape[1]
    logits, cache = model.prefill(params, {"tokens": toks[:, :s]},
                                  max_len=t, serve=serve)
    step_fn = _decode_fn(model, serve)
    outs = [logits]
    for i in range(s, t):
        step, cache = step_fn(params, cache, toks[:, i])
        outs.append(step[:, None])
    return jnp.concatenate(outs, axis=1), cache


# ---------------------------------------------------------------------------
# kernel-level: swa_decode ref/pallas parity + position contract
# ---------------------------------------------------------------------------

def _qkc(n, g, c, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((n, g, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((n, c, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, c, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("window,pos", [
    (8, 0), (8, 7), (8, 8), (8, 29),        # ring: pre-fill, boundary, wrap
    (0, 0), (0, 5), (0, 15),                # dense full causal
])
def test_swa_decode_ref_pallas_parity(g, window, pos):
    c = window or 16
    q, k, v = _qkc(2, g, c, 32, seed=pos + 10 * g)
    p = jnp.full((2,), pos, jnp.int32)
    want = dispatch.swa_decode(q, k, v, p, window=window, backend="ref")
    got = dispatch.swa_decode(q, k, v, p, window=window, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_swa_decode_fp8_scales_parity():
    from repro.quant import quant
    q, k, v = _qkc(3, 2, 64, 32, seed=3)
    kp, ks = quant.quantize_rows(k, "e4m3", "fp32")
    vp, vs = quant.quantize_rows(v, "e4m3", "fp32")
    pos = jnp.asarray([0, 63, 64 * 3 + 7], jnp.int32)   # mixed depths
    want = dispatch.swa_decode(q, kp, vp, pos, window=64, k_scale=ks,
                               v_scale=vs, backend="ref")
    got = dispatch.swa_decode(q, kp, vp, pos, window=64, k_scale=ks,
                              v_scale=vs, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_slot_positions_contract():
    """The ring holds exactly the last min(pos+1, C) positions, the newest
    in slot pos % C, and the next write evicts the oldest visible key."""
    c = 8
    for pos in (0, 3, 7, 8, 21):
        p = np.asarray(ref.swa_decode_slot_positions(
            jnp.asarray([pos], jnp.int32), c))[0]
        valid = p[(p >= 0) & (p <= pos) & (p > pos - c)]
        want = np.arange(max(0, pos - c + 1), pos + 1)
        assert sorted(valid.tolist()) == want.tolist()
        assert p[pos % c] == pos                       # newest
        if pos + 1 >= c:
            # mask/eviction agreement at the window boundary: the oldest
            # in-window key sits in the slot the NEXT token overwrites
            assert p[(pos + 1) % c] == pos - c + 1


def test_decode_visible_count_pins_window_semantics():
    """min(pos + 1, window) keys: compare the ring decode against a
    materialized softmax over exactly that key set."""
    c, hd = 8, 16
    rng = np.random.default_rng(0)
    hist = jnp.asarray(rng.standard_normal((30, hd)), jnp.float32)  # k==v
    for pos in (0, 4, 7, 8, 20):
        # build the ring state after writing positions 0..pos
        kcache = np.zeros((c, hd), np.float32)
        for pp in range(pos + 1):
            kcache[pp % c] = np.asarray(hist[pp])
        q = jnp.asarray(rng.standard_normal((1, 1, hd)), jnp.float32)
        out = dispatch.swa_decode(q, jnp.asarray(kcache)[None],
                                  jnp.asarray(kcache)[None],
                                  jnp.asarray([pos], jnp.int32),
                                  window=c, backend="ref")
        lo = max(0, pos - c + 1)
        keys = hist[lo:pos + 1]                       # min(pos+1, c) keys
        assert keys.shape[0] == min(pos + 1, c)
        s = (q[0] @ keys.T) * hd ** -0.5
        want = jax.nn.softmax(s, -1) @ keys
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# model-level: prefill + decode vs the teacher-forced training forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("window", [0, 4])     # 4 == S/4: ring wraps below
@pytest.mark.parametrize("n_kv", [1, 4])       # GQA group sizes 4 and 1
def test_decode_matches_teacher_forced(n_kv, window, backend):
    model, params = _model(n_kv_heads=n_kv, window=window, backend=backend)
    s, t = 8, 16                               # t - s > window: wraps twice
    toks = _tokens(2, t, model.cfg.vocab, seed=n_kv)
    serve = (ServeConfig(kv_cache="ring", kv_dtype="f32", backend=backend)
             if window else
             ServeConfig(kv_cache="dense", kv_dtype="f32", backend=backend))
    full, _ = model.forward(params, {"tokens": toks})
    dec, cache = _teacher_forced_decode(model, params, toks, s, serve)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=1e-5, rtol=1e-4)
    # ring wraparound really happened: positions advanced past capacity
    if window:
        assert t > window and int(cache["len"][0]) == t


def test_fp8_ring_cache():
    """fp8 e4m3 payload + per-row scales: ref/pallas agree tightly; the
    deviation from the exact teacher-forced forward is bounded by e4m3
    rounding (documented LOOSE tolerance — fp8 KV is lossy by design, so
    ~1e-2-scale relative logit error through 2 layers is expected, nothing
    like the f32 paths' 1e-5)."""
    model, params = _model(n_kv_heads=1, window=4, backend="ref")
    s, t = 8, 16
    toks = _tokens(2, t, model.cfg.vocab, seed=7)
    dec_ref, _ = _teacher_forced_decode(
        model, params, toks, s,
        ServeConfig(kv_cache="ring", kv_dtype="fp8_e4m3", backend="ref"))
    dec_pal, _ = _teacher_forced_decode(
        model, params, toks, s,
        ServeConfig(kv_cache="ring", kv_dtype="fp8_e4m3", backend="pallas"))
    np.testing.assert_allclose(np.asarray(dec_pal), np.asarray(dec_ref),
                               atol=1e-4, rtol=1e-4)
    full, _ = model.forward(params, {"tokens": toks})
    err = float(jnp.abs(dec_ref - full).max())
    scale = float(jnp.abs(full).max())
    assert err <= 0.15 * max(scale, 1.0), (err, scale)


def test_fp8_cache_bytes_ratio():
    """Acceptance: fp8 ring cache <= 0.3x the f32 ring cache bytes (the
    analytic ratio is (hd + 4) / (4 hd) ~= 0.266 at hd=64)."""
    model, _ = _model(n_kv_heads=1, window=16)
    fp8 = cache_bytes(model.init_cache(
        2, 64, serve=ServeConfig(kv_cache="ring", kv_dtype="fp8_e4m3")))
    f32 = cache_bytes(model.init_cache(
        2, 64, serve=ServeConfig(kv_cache="ring", kv_dtype="f32")))
    assert fp8 <= 0.3 * f32, (fp8, f32)


def test_ring_capacity_caps_cache_to_window():
    """The ring allocates window slots, not max_len."""
    model, _ = _model(n_kv_heads=1, window=4)
    c = model.init_cache(2, 64,
                         serve=ServeConfig(kv_cache="ring", kv_dtype="f32"))
    assert c["k"].shape[2] == 4
    assert c["len"].shape == (2,)


def test_legacy_dense_clamp_matches_teacher_forced():
    """The decode-span clamp (slice min(window, max_len) keys out of the
    padded cache instead of masking all of it) is numerically invisible:
    legacy decode logits still match the teacher-forced forward through
    positions where the clamp start is 0, sliding, and saturated."""
    model, params = _model(n_kv_heads=1, window=4, backend="ref")
    s, t = 4, 16
    toks = _tokens(2, t, model.cfg.vocab, seed=11)
    full, _ = model.forward(params, {"tokens": toks})
    logits, cache = model.prefill(params, {"tokens": toks[:, :s]},
                                  max_len=t + 8)   # max_len > t: padded tail
    step_fn = _decode_fn(model, None)
    outs = [logits]
    for i in range(s, t):
        step, cache = step_fn(params, cache, toks[:, i])
        outs.append(step[:, None])
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=1e-5, rtol=1e-4)


def test_serve_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(kv_cache="dense", kv_dtype="fp8_e4m3")
    with pytest.raises(ValueError):
        ServeConfig(kv_cache="paged")
    model, _ = _model(n_kv_heads=1, window=4)
    with pytest.raises(ValueError):
        # windowed dense serve cache is the legacy path's job
        model.init_cache(1, 8, serve=ServeConfig(kv_cache="dense",
                                                 kv_dtype="f32"))


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

def test_batcher_matches_solo_decode():
    """Admit/evict churn (4 variable-length requests through 2 slots) must
    not perturb any sequence: batched greedy output == solo batch-1 decode
    token for token."""
    model, params = _model(n_kv_heads=1, window=4, backend="ref")
    serve = ServeConfig(kv_cache="ring", kv_dtype="f32")
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(0, model.cfg.vocab, (int(n),)),
                    max_new=g, uid=i)
            for i, (n, g) in enumerate([(5, 4), (3, 6), (7, 3), (4, 5)])]
    bat = ContinuousBatcher(model, params, serve, slots=2, max_len=24)
    got = bat.run(list(reqs))

    step_fn = _decode_fn(model, serve)
    for r in reqs:
        lg, cache = model.prefill(
            params, {"tokens": jnp.asarray(r.prompt)[None]}, 24, serve=serve)
        tok = int(jnp.argmax(lg[0, -1]))
        want = [tok]
        for _ in range(r.max_new - 1):
            lg, cache = step_fn(params, cache, jnp.asarray([tok], jnp.int32))
            tok = int(jnp.argmax(lg[0]))
            want.append(tok)
        assert got[r.uid] == want, r.uid


def test_batcher_bucketed_prefill_parity():
    """Power-of-two prompt bucketing is invisible: padded prefill (3 -> 4,
    5/6 -> 8) produces the same tokens as the exact-shape solo decode, and
    the jit cache holds one program per bucket, not one per length."""
    model, params = _model(n_kv_heads=1, window=8, backend="ref")
    serve = ServeConfig(kv_cache="ring", kv_dtype="f32")
    rng = np.random.default_rng(13)
    reqs = [Request(prompt=rng.integers(0, model.cfg.vocab, (int(n),)),
                    max_new=4, uid=i)
            for i, n in enumerate([3, 5, 6, 5])]
    bat = ContinuousBatcher(model, params, serve, slots=2, max_len=24)
    got = bat.run(list(reqs))
    assert set(bat._prefill) == {4, 8}          # buckets, not raw lengths

    step_fn = _decode_fn(model, serve)
    for r in reqs:
        lg, cache = model.prefill(
            params, {"tokens": jnp.asarray(r.prompt)[None]}, 24, serve=serve)
        tok = int(jnp.argmax(lg[0, -1]))
        want = [tok]
        for _ in range(r.max_new - 1):
            lg, cache = step_fn(params, cache, jnp.asarray([tok], jnp.int32))
            tok = int(jnp.argmax(lg[0]))
            want.append(tok)
        assert got[r.uid] == want, r.uid


def test_batcher_bucket_clamps_to_ring_capacity():
    """A bucket past the ring capacity would wrap pad writes over real
    in-window keys; those prompts fall back to exact-shape prefill."""
    model, params = _model(n_kv_heads=1, window=4, backend="ref")
    serve = ServeConfig(kv_cache="ring", kv_dtype="f32")
    rng = np.random.default_rng(3)
    bat = ContinuousBatcher(model, params, serve, slots=1, max_len=16)
    bat.run([Request(prompt=rng.integers(0, model.cfg.vocab, (7,)),
                     max_new=2, uid=0),
             Request(prompt=rng.integers(0, model.cfg.vocab, (3,)),
                     max_new=2, uid=1)])
    assert set(bat._prefill) == {7, 4}   # 7: exact fallback; 3: bucket 4


def test_batcher_sampling_deterministic_and_slot_invariant():
    """A sampled request's tokens are a pure function of (seed, uid,
    prompt, max_new): identical across reruns and across different slot
    counts (admission interleavings); a different seed moves the output."""
    model, params = _model(n_kv_heads=1, window=4, backend="ref")
    serve = ServeConfig(kv_cache="ring", kv_dtype="f32")
    rng = np.random.default_rng(21)
    reqs = [Request(prompt=rng.integers(0, model.cfg.vocab, (4,)),
                    max_new=6, uid=i) for i in range(3)]
    kw = dict(slots=2, max_len=16, temperature=0.8, top_k=8, seed=42)
    a = ContinuousBatcher(model, params, serve, **kw).run(list(reqs))
    b = ContinuousBatcher(model, params, serve, **kw).run(list(reqs))
    assert a == b
    c = ContinuousBatcher(model, params, serve, slots=3, max_len=16,
                          temperature=0.8, top_k=8, seed=42).run(list(reqs))
    assert a == c    # per-uid streams: lane assignment never perturbs them
    d = ContinuousBatcher(model, params, serve, slots=2, max_len=16,
                          temperature=0.8, top_k=8, seed=7).run(list(reqs))
    assert d != a


def test_batcher_temperature_zero_is_greedy():
    """temperature=0 keeps the greedy program (seed is irrelevant), and
    top_k=1 reduces sampling to argmax at any temperature."""
    model, params = _model(n_kv_heads=1, window=4, backend="ref")
    serve = ServeConfig(kv_cache="ring", kv_dtype="f32")
    rng = np.random.default_rng(17)
    reqs = [Request(prompt=rng.integers(0, model.cfg.vocab, (4,)),
                    max_new=5, uid=i) for i in range(2)]
    greedy = ContinuousBatcher(model, params, serve,
                               slots=2, max_len=16).run(list(reqs))
    t0 = ContinuousBatcher(model, params, serve, slots=2, max_len=16,
                           temperature=0.0, seed=123).run(list(reqs))
    assert t0 == greedy
    k1 = ContinuousBatcher(model, params, serve, slots=2, max_len=16,
                           temperature=0.7, top_k=1, seed=5).run(list(reqs))
    assert k1 == greedy


def test_batcher_slot_reuse():
    """A drained slot is re-admitted immediately and the reused lane's
    stale ring contents never leak into the new sequence."""
    model, params = _model(n_kv_heads=1, window=4, backend="ref")
    serve = ServeConfig(kv_cache="ring", kv_dtype="f32")
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, model.cfg.vocab, (4,)) for _ in range(3)]
    # one slot only: every request reuses the same lane back to back
    bat = ContinuousBatcher(model, params, serve, slots=1, max_len=16)
    got = bat.run([Request(prompt=p, max_new=3, uid=i)
                   for i, p in enumerate(prompts)])
    step_fn = _decode_fn(model, serve)
    for i, p in enumerate(prompts):
        lg, cache = model.prefill(params, {"tokens": jnp.asarray(p)[None]},
                                  16, serve=serve)
        tok = int(jnp.argmax(lg[0, -1]))
        want = [tok]
        for _ in range(2):
            lg, cache = step_fn(params, cache, jnp.asarray([tok], jnp.int32))
            tok = int(jnp.argmax(lg[0]))
            want.append(tok)
        assert got[i] == want, i
