"""Per-architecture smoke tests (deliverable f): every assigned architecture
instantiated as a REDUCED variant of the same family (2 layers, d_model<=512,
<=4 experts), one forward + one SP-NGD train step on CPU, asserting output
shapes and absence of NaNs. Decode (serve_step) is exercised too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core.ngd import NGDConfig, SPNGD
from repro.models.transformer import DecoderLM

LM_ARCHS = [a for a in list_archs() if a != "resnet50"]

# full per-arch train-step sweep is the most expensive part of the suite:
# keep one dense representative in the default run, mark the rest slow
_TRAIN_STEP_FAST = ("llama3_2_1b",)
_TRAIN_STEP_ARCHS = [a if a in _TRAIN_STEP_FAST
                     else pytest.param(a, marks=pytest.mark.slow)
                     for a in LM_ARCHS]


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["pixel_embeds"] = jnp.asarray(
            rng.randn(b, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    m = DecoderLM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
    s_total = 16 + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, s_total, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", _TRAIN_STEP_ARCHS)
def test_one_spngd_train_step(arch):
    cfg = get_config(arch).reduced()
    m = DecoderLM(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, seed=1)
    opt = SPNGD(m.loss, m.site_infos(), m.fstats, m.site_counts,
                NGDConfig(damping=1e-3))
    state = opt.init(params)
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    new_params, state, metrics = jax.jit(opt.step)(
        params, state, batch, flags, 1e-3, 1e-2, 0.9)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved and stayed finite
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    m = DecoderLM(cfg)
    params = m.init(jax.random.PRNGKey(2))
    cache = m.init_cache(2, 24)
    tok = jnp.ones((2,), jnp.int32)
    step = jax.jit(m.decode_step)
    for _ in range(3):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (2, cfg.vocab)
    assert int(cache["len"]) == 3
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", [
    "llama3_2_1b", "rwkv6_7b",
    pytest.param("hymba_1_5b", marks=pytest.mark.slow)])
def test_prefill_then_decode_consistency(arch):
    """Decoding token-by-token must match the teacher-forced forward."""
    cfg = get_config(arch).reduced()
    m = DecoderLM(cfg)
    params = m.init(jax.random.PRNGKey(3))
    rng = np.random.RandomState(3)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 8)), jnp.int32)
    full_logits, _ = m.forward(params, {"tokens": toks})
    cache = m.init_cache(1, 16)
    outs = []
    for i in range(8):
        logits, cache = m.decode_step(params, cache, toks[:, i])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.slow
def test_resnet_smoke():
    from repro.configs import get_config
    from repro.models.resnet import ConvNet
    cfg = get_config("resnet50")
    model = ConvNet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = {"images": jnp.asarray(rng.randn(4, 16, 16, 3), jnp.float32),
             "labels": jnp.asarray(rng.randint(0, 10, 4), jnp.int32)}
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3,
                                             weight_rescale=True))
    state = opt.init(params)
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    new_params, state, metrics = jax.jit(opt.step)(
        params, state, batch, flags, 1e-3, 1e-2, 0.9)
    assert np.isfinite(float(metrics["loss"]))
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_full_configs_match_assignment():
    """The FULL configs must carry the exact assigned hyperparameters."""
    expect = {
        "qwen1_5_4b": (40, 2560, 20, 20, 6912, 151936),
        "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "llama3_2_1b": (16, 2048, 32, 8, 8192, 128256),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
        "nemotron_4_340b": (96, 18432, 96, 8, 73728, 256000),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "llama3_2_3b": (28, 3072, 24, 8, 8192, 128256),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch
    assert get_config("qwen1_5_4b").qkv_bias
    assert get_config("mixtral_8x22b").n_experts == 8
    assert get_config("mixtral_8x22b").top_k == 2
    assert get_config("mixtral_8x22b").sliding_window > 0
    assert get_config("qwen2_moe_a2_7b").n_experts == 60
    assert get_config("qwen2_moe_a2_7b").top_k == 4
    assert get_config("qwen2_moe_a2_7b").n_shared_experts == 4
    assert get_config("hymba_1_5b").ssm_state == 16
    assert get_config("nemotron_4_340b").act == "relu2"
