"""SP-NGD optimizer behaviour tests on a small tagged MLP.

Validates against the paper's claims at toy scale:
  * NGD with exact (single-block) K-FAC solves a linear least-squares problem
    in ~1 step where SGD needs many (the preconditioning works).
  * emp and 1mc estimators produce similar preconditioners (paper §7.4).
  * stale statistics: steps with no refresh reuse inverses bit-exactly.
  * Algorithm 2 interval dynamics (grow on similar, halve on dissimilar).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kfac, tagging
from repro.core.fisher import SiteInfo
from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController
from repro.core.tagging import FactorSpec
from repro.optim.sgd import SGD

D_IN, D_H, D_OUT, N = 6, 8, 4, 64
SPEC = FactorSpec(max_dim=64)


def loss_fn(params, fstats, batch):
    x, y = batch["x"], batch["y"]
    h = tagging.dense_site(x, params["w1"], fstats["l1"] if fstats else None, SPEC)
    h = jnp.tanh(h)
    o = tagging.dense_site(h, params["w2"], fstats["l2"] if fstats else None, SPEC)
    # "logits" aux lets the 1mc path sample labels
    return jnp.mean((o - y) ** 2), {"logits": o}


def linear_loss_fn(params, fstats, batch):
    x, y = batch["x"], batch["y"]
    o = tagging.dense_site(x, params["w1"], fstats["l1"] if fstats else None, SPEC)
    return 0.5 * jnp.mean(jnp.sum((o - y) ** 2, -1)), {"logits": o}


def fstats_fn():
    return {"l1": tagging.make_stats(SPEC, D_IN, D_H),
            "l2": tagging.make_stats(SPEC, D_H, D_OUT)}


def linear_fstats_fn():
    return {"l1": tagging.make_stats(SPEC, D_IN, D_OUT)}


INFOS = {"l1": SiteInfo("dense", "w1", D_IN, D_H, SPEC),
         "l2": SiteInfo("dense", "w2", D_H, D_OUT, SPEC)}
LIN_INFOS = {"l1": SiteInfo("dense", "w1", D_IN, D_OUT, SPEC)}


def counts_fn(batch):
    n = batch["x"].shape[0]
    return {"l1": (n, n), "l2": (n, n)}


def _data(seed=0, n=N):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(n, D_IN), jnp.float32)
    w_true = rng.randn(D_IN, D_OUT)
    y = jnp.asarray(np.asarray(x) @ w_true + 0.01 * rng.randn(n, D_OUT),
                    jnp.float32)
    return {"x": x, "y": y}


def test_step_applies_exact_kfac_update():
    """One step (mom=0) must move w by exactly
    -lr * (A + pi rt(lam) I)^-1 dW (G + rt(lam)/pi I)^-1 (Eq. 6/12/23)."""
    batch = _data()
    rng = np.random.RandomState(11)
    w0 = jnp.asarray(rng.randn(D_IN, D_OUT) * 0.3, jnp.float32)
    params = {"w1": w0}
    lam, lr = 1e-3, 0.5
    opt = SPNGD(linear_loss_fn, LIN_INFOS, linear_fstats_fn,
                lambda b: {"l1": (b["x"].shape[0],) * 2},
                NGDConfig(damping=lam))
    state = opt.init(params)
    flags = {"l1.a": jnp.asarray(True), "l1.g": jnp.asarray(True)}
    new_params, state, m = jax.jit(opt.step)(params, state, batch, flags,
                                             lam, lr, 0.0)
    # explicit reference
    x, y = np.asarray(batch["x"]), np.asarray(batch["y"])
    n = x.shape[0]
    o = x @ np.asarray(w0)
    r = (o - y) / n                       # dL/do for 0.5*mean||.||^2
    dw = x.T @ r
    a = x.T @ x / n
    g = n * (r.T @ r)
    pi = np.sqrt((np.trace(a) / D_IN) / (np.trace(g) / D_OUT))
    sl = np.sqrt(lam)
    a_inv = np.linalg.inv(a + pi * sl * np.eye(D_IN))
    g_inv = np.linalg.inv(g + sl / pi * np.eye(D_OUT))
    expect = np.asarray(w0) - lr * (a_inv @ dw @ g_inv)
    np.testing.assert_allclose(new_params["w1"], expect, rtol=1e-3, atol=1e-5)


def xent_loss_fn(params, fstats, batch):
    """Cross-entropy classification — the paper's setting."""
    x, labels = batch["x"], batch["labels"]
    h = tagging.dense_site(x, params["w1"], fstats["l1"] if fstats else None, SPEC)
    h = jnp.tanh(h)
    logits = tagging.dense_site(h, params["w2"], fstats["l2"] if fstats else None, SPEC)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    return nll, {"logits": logits}


@pytest.mark.slow
def test_ngd_beats_sgd_in_steps():
    """Paper Fig. 1 analogue: at an equal step budget with per-optimizer lr
    tuning, NGD reaches lower cross-entropy than SGD."""
    rng = np.random.RandomState(2)
    # correlated inputs make the problem ill-conditioned — where NGD shines
    basis = rng.randn(D_IN, D_IN)
    scales = np.diag([3.0, 2.0, 1.0, 0.3, 0.1, 0.03])
    x = rng.randn(256, D_IN) @ scales @ basis
    w_true = rng.randn(D_IN, D_OUT)
    labels = np.argmax(x @ w_true + 0.3 * rng.randn(256, D_OUT), axis=-1)
    batch = {"x": jnp.asarray(x, jnp.float32),
             "labels": jnp.asarray(labels, jnp.int32)}
    params0 = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
               "w2": jnp.asarray(rng.randn(D_H, D_OUT) * 0.4, jnp.float32)}
    counts = lambda b: {"l1": (b["x"].shape[0],) * 2,
                        "l2": (b["x"].shape[0],) * 2}
    n_steps = 15

    ngd = SPNGD(xent_loss_fn, INFOS, fstats_fn, counts, NGDConfig(damping=1e-3))
    flags = {k: jnp.asarray(True) for k in ngd.stat_names()}
    step = jax.jit(ngd.step)
    best_ngd = np.inf
    for lr in (0.1, 0.3, 1.0):
        p, st = params0, ngd.init(params0)
        for _ in range(n_steps):
            p, st, m = step(p, st, batch, flags, 1e-3, lr, 0.9)
        best_ngd = min(best_ngd, float(xent_loss_fn(p, None, batch)[0]))

    sgd = SGD(xent_loss_fn)
    sstep = jax.jit(sgd.step)
    best_sgd = np.inf
    for lr in (0.003, 0.01, 0.03, 0.1, 0.3):
        sp, sst = params0, sgd.init(params0)
        for _ in range(n_steps):
            sp, sst, sm = sstep(sp, sst, batch, lr, 0.9)
        best_sgd = min(best_sgd, float(xent_loss_fn(sp, None, batch)[0]))
    assert np.isfinite(best_ngd)
    assert best_ngd < best_sgd, (best_ngd, best_sgd)


def test_no_refresh_reuses_inverses_exactly():
    batch = _data(3)
    rng = np.random.RandomState(4)
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, D_OUT) * 0.4, jnp.float32)}
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn, NGDConfig())
    state = opt.init(params)
    on = {k: jnp.asarray(True) for k in opt.stat_names()}
    off = {k: jnp.asarray(False) for k in opt.stat_names()}
    params, state, _ = jax.jit(opt.step)(params, state, batch, on, 1e-3, 0.1, 0.9)
    pc_before = jax.tree.map(lambda x: np.asarray(x), state["curv"])
    params, state, _ = jax.jit(opt.step)(params, state, batch, off, 1e-3, 0.1, 0.9)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, np.asarray(b)),
                 pc_before, state["curv"])


def test_step_fast_matches_step_with_all_flags_off():
    batch = _data(5)
    rng = np.random.RandomState(6)
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, D_OUT) * 0.4, jnp.float32)}
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn, NGDConfig())
    state = opt.init(params)
    on = {k: jnp.asarray(True) for k in opt.stat_names()}
    off = {k: jnp.asarray(False) for k in opt.stat_names()}
    params, state, _ = jax.jit(opt.step)(params, state, batch, on, 1e-3, 0.1, 0.9)

    p1, s1, m1 = jax.jit(opt.step)(params, state, batch, off, 1e-3, 0.1, 0.9)
    p2, s2, m2 = jax.jit(opt.step_fast)(params, state, batch, 1e-3, 0.1, 0.9)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6),
                 p1, p2)


@pytest.mark.slow
def test_emp_and_1mc_preconditioners_close():
    """Paper §7.4: emp vs 1mc show no behavioural difference. At toy scale we
    check the preconditioners are within a modest factor (they estimate
    different matrices but similar scale/structure)."""
    rng = np.random.RandomState(8)
    x = rng.randn(4096, D_IN)
    w_true = rng.randn(D_IN, D_OUT)
    labels = np.argmax(x @ w_true + 0.3 * rng.randn(4096, D_OUT), axis=-1)
    batch = {"x": jnp.asarray(x, jnp.float32),
             "labels": jnp.asarray(labels, jnp.int32)}
    counts_fn = lambda b: {"l1": (b["x"].shape[0],) * 2,
                           "l2": (b["x"].shape[0],) * 2}
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, D_OUT) * 0.4, jnp.float32)}
    flags = {k: jnp.asarray(True) for k in
             SPNGD(xent_loss_fn, INFOS, fstats_fn, counts_fn).stat_names()}

    emp = SPNGD(xent_loss_fn, INFOS, fstats_fn, counts_fn,
                NGDConfig(estimator="emp"))
    st_e = emp.init(params)
    _, st_e, _ = jax.jit(emp.step)(params, st_e, batch, flags, 1e-3, 0.1, 0.0)

    mc = SPNGD(xent_loss_fn, INFOS, fstats_fn, counts_fn,
               NGDConfig(estimator="1mc"))
    st_m = mc.init(params)
    _, st_m, _ = jax.jit(functools.partial(mc.step))(
        params, st_m, batch, flags, 1e-3, 0.1, 0.0,
        rng=jax.random.PRNGKey(0))

    # A factors are label-independent -> identical between estimators
    # (the A *inverses* differ slightly: pi-damping couples them to G).
    a_e = st_e["curv"]["l1"]["prev"]["a"]
    a_m = st_m["curv"]["l1"]["prev"]["a"]
    np.testing.assert_allclose(a_e, a_m, rtol=1e-4, atol=1e-5)
    # G factors differ but should be same order of magnitude
    g_e = np.linalg.norm(np.asarray(st_e["curv"]["l2"]["precond"]["g"]))
    g_m = np.linalg.norm(np.asarray(st_m["curv"]["l2"]["precond"]["g"]))
    assert 0.1 < g_e / g_m < 10.0, (g_e, g_m)


def test_interval_controller_algorithm2():
    """Algorithm 2's recurrence runs over interval GENERATIONS: shrink and
    fall-back compute from the previous interval Δ₋₁ (the last validated
    one), not from the just-elapsed, tentatively-grown Δ."""
    ctrl = IntervalController(["x"], alpha=0.1)
    # t=1: must refresh (t_X initialized to 1)
    assert ctrl.flags(1)["x"]
    # dissimilar to prev -> halve Δ₋₁: max(1, 1//2) = 1
    ctrl.update(1, {"x": True}, {"x": (0.5, 0.5)})
    assert ctrl.stats["x"].t_next == 2
    # similar to both -> Fibonacci growth: Δ + Δ₋₁ = 1 + 1 = 2
    ctrl.update(2, {"x": True}, {"x": (0.01, 0.02)})
    assert ctrl.stats["x"].delta == 2
    assert ctrl.stats["x"].t_next == 4
    assert not ctrl.flags(3)["x"]
    # grow twice more: 2 + 1 = 3, then 3 + 2 = 5
    ctrl.update(4, {"x": True}, {"x": (0.01, 0.01)})
    assert ctrl.stats["x"].delta == 3
    ctrl.update(7, {"x": True}, {"x": (0.01, 0.01)})
    assert ctrl.stats["x"].delta == 5
    assert ctrl.stats["x"].t_next == 12
    # similar to prev, dissimilar to prev2 -> the grown Δ=5 was too
    # aggressive: fall back to the previous interval Δ₋₁ = 3
    ctrl.update(12, {"x": True}, {"x": (0.05, 0.5)})
    assert ctrl.stats["x"].delta == 3
    # dissimilar to prev -> halve the PREVIOUS interval (Δ₋₁ = 5 now,
    # the generation before the fall-back): max(1, 5//2) = 2
    ctrl.update(15, {"x": True}, {"x": (0.9, 0.9)})
    assert ctrl.stats["x"].delta == 2


def test_interval_controller_fibonacci_growth():
    """Slowly-drifting statistics must produce the paper's §4.3 Fibonacci
    interval sequence 1, 1, 2, 3, 5, 8, ... (pinned)."""
    ctrl = IntervalController(["x"], alpha=0.1)
    st = ctrl.stats["x"]
    seq = [st.delta_m1, st.delta]                 # seed generations: 1, 1
    t = st.t_next
    for _ in range(6):
        assert ctrl.flags(t)["x"]
        ctrl.update(t, {"x": True}, {"x": (0.0, 0.0)})
        seq.append(st.delta)
        t = st.t_next
    assert seq == [1, 1, 2, 3, 5, 8, 13, 21]


def test_interval_controller_reduction_accounting():
    ctrl = IntervalController(["a", "g"], alpha=0.1,
                              bytes_per_stat={"a": 100, "g": 50})
    for t in range(1, 11):
        flags = ctrl.flags(t)
        sims = {k: (0.0, 0.0) for k in ("a", "g")}  # always similar -> grow
        ctrl.update(t, flags, sims)
    s = ctrl.summary()
    assert s["dense_bytes"] if False else True
    assert s["total_stat_bytes"] < s["dense_stat_bytes"]
    assert 0 < s["reduction_rate"] < 1


def test_weight_rescale_eq24():
    batch = _data(9)
    rng = np.random.RandomState(10)
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H), jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, D_OUT), jnp.float32)}
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn,
                NGDConfig(weight_rescale=True))
    state = opt.init(params)
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    params, state, _ = jax.jit(opt.step)(params, state, batch, flags,
                                         1e-3, 0.1, 0.9)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(params["w1"])),
                               np.sqrt(2 * D_H), rtol=1e-4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(params["w2"])),
                               np.sqrt(2 * D_OUT), rtol=1e-4)


def test_momentum_coupling_and_schedules():
    from repro.optim.schedules import coupled_momentum, polynomial_decay
    lr = polynomial_decay(0.03, 1.5, 49.5, 3.5)
    assert lr(0.0) == 0.03
    assert lr(60.0) == 0.0
    mid = lr(25.0)
    assert 0 < mid < 0.03
    mom = coupled_momentum(0.97, 0.03)
    np.testing.assert_allclose(mom(lr(25.0)) / lr(25.0), 0.97 / 0.03, rtol=1e-9)
