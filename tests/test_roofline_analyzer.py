"""Validates the trip-weighted HLO analyzer against XLA's own cost_analysis
(exact on loop-free programs) and against unrolled-vs-scanned equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import compat
from repro.launch.roofline import analyze_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_dot_flops_match_cost_analysis():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    c = _compile(lambda a, b: a @ b, a, b)
    ana = analyze_hlo(c.as_text())
    expect = 2 * 128 * 256 * 64
    assert abs(ana.flops - expect) / expect < 0.05, (ana.flops, expect)
    ca = compat.cost_analysis(c)
    if ca and ca.get("flops"):
        assert abs(ana.flops - ca["flops"]) / ca["flops"] < 0.1


def test_chained_dots():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(a):
        for _ in range(4):
            a = jnp.tanh(a @ a)
        return a

    c = _compile(f, a)
    ana = analyze_hlo(c.as_text())
    expect = 4 * 2 * 64 ** 3
    assert abs(ana.flops - expect) / expect < 0.1, (ana.flops, expect)


def test_scan_flops_are_trip_weighted():
    """A scanned matmul must count trips x body flops (cost_analysis gets
    this wrong; our analyzer must not)."""
    w = jnp.zeros((16, 64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def scanned(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    def unrolled(w, x):
        h = x
        for i in range(16):
            h = jnp.tanh(h @ w[i])
        return h

    c_s = _compile(scanned, w, x)
    c_u = _compile(unrolled, w, x)
    f_s = analyze_hlo(c_s.as_text()).flops
    f_u = analyze_hlo(c_u.as_text()).flops
    expect = 16 * 2 * 8 * 64 * 64
    assert abs(f_u - expect) / expect < 0.1, (f_u, expect)
    assert abs(f_s - expect) / expect < 0.15, (f_s, expect)


def test_nested_scan_weighting():
    w = jnp.zeros((4, 64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def f(w, x):
        def outer(h, _):
            def inner(h2, wl):
                return jnp.tanh(h2 @ wl), None
            h, _ = jax.lax.scan(inner, h, w)
            return h, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    c = _compile(f, w, x)
    ana = analyze_hlo(c.as_text())
    expect = 3 * 4 * 2 * 8 * 64 * 64
    assert abs(ana.flops - expect) / expect < 0.2, (ana.flops, expect)


def test_collective_bytes_counted():
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >= 2 devices")
    mesh = compat.make_mesh((2,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(a):
        b = jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, P("x", None)))
        return jnp.sum(b * 2.0)          # all-reduce at the end

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, "x")))
    with compat.set_mesh(mesh):
        c = jax.jit(f).lower(a).compile()
    ana = analyze_hlo(c.as_text())
    assert ana.collective_bytes > 0
    assert sum(ana.count_by_kind.values()) >= 1


def test_hbm_bytes_reasonable():
    a = jnp.zeros((512, 512), jnp.float32)
    c = _compile(lambda a: a @ a, a)
    ana = analyze_hlo(c.as_text())
    lo = 3 * 512 * 512 * 4               # read a twice + write out
    assert ana.hbm_bytes >= lo * 0.5
    assert ana.hbm_bytes <= lo * 20
