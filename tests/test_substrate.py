"""Substrate coverage: augmentation (paper §6.1), checkpointing, stale
accounting, input_specs, chunked recurrent scans."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config, INPUT_SHAPES
from repro.core.stale import sym_packed_bytes
from repro.data.augment import RunningMixup, random_erase
from repro.data.synthetic import token_batches, image_batches
from repro.models.transformer import DecoderLM


def test_running_mixup_eq18_19():
    """x~(t) mixes with the PREVIOUS virtual batch, not the raw one."""
    mix = RunningMixup(alpha=1e6, n_classes=4, seed=0)  # lam ~= 0.5 w.h.p.
    x1 = jnp.ones((2, 4, 4, 3))
    y1 = jnp.asarray([0, 1])
    out1, t1 = mix(x1, y1)
    np.testing.assert_array_equal(out1, x1)             # first step: raw
    x2 = jnp.zeros((2, 4, 4, 3))
    out2, t2 = mix(x2, jnp.asarray([2, 3]))
    # mixed towards the previous virtual batch (ones)
    assert 0.0 < float(out2.mean()) < 1.0
    np.testing.assert_allclose(np.asarray(t2).sum(-1), 1.0, rtol=1e-5)
    # step 3 mixes with step-2 virtual, not with x1
    out3, _ = mix(x1, y1)
    assert not np.allclose(out3, out1)


def test_random_erase_zero_value():
    rng = np.random.RandomState(0)
    imgs = np.ones((16, 24, 24, 3), np.float32)
    out = random_erase(rng, imgs, p=1.0)
    assert (out == 0).any()                             # erased with ZEROS
    assert out.min() == 0.0 and out.max() == 1.0
    # originals untouched
    assert imgs.min() == 1.0


def test_markov_lm_is_learnable_signal():
    it = token_batches(64, 4, 32, seed=0)
    b1, b2 = next(it), next(it)
    assert b1["tokens"].shape == (4, 32)
    # labels are the next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    params = {"a": {"b": jnp.arange(6.0).reshape(2, 3)},
              "c": jnp.ones((4,), jnp.int32)}
    opt = {"step": jnp.asarray(7), "velocity": {"a": {"b": jnp.zeros((2, 3))},
                                                "c": jnp.zeros((4,), jnp.int32)}}
    save_checkpoint(str(tmp_path), 7, params, opt, {"delta": 3})
    r = restore_checkpoint(str(tmp_path))
    assert r["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 params, r["params"])
    assert r["controller"]["delta"] == 3


def test_sym_packed_bytes():
    assert sym_packed_bytes((4, 4)) == 10 * 4           # n(n+1)/2 * f32
    assert sym_packed_bytes((3, 4, 4)) == 3 * 10 * 4    # leading axes multiply
    assert sym_packed_bytes((5,)) == 5 * 4              # non-square: full


@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_shapes(shape_name):
    cfg = get_config("llama3_2_1b")
    model = DecoderLM(cfg)
    shape = INPUT_SHAPES[shape_name]
    specs = model.input_specs(shape)
    if shape.kind == "train":
        assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
        assert specs["labels"].shape == (shape.global_batch, shape.seq_len)
    elif shape.kind == "prefill":
        assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
    else:
        assert specs["tokens"].shape == (shape.global_batch,)
        assert specs["cache"]["k"].shape[2] == shape.seq_len
    # pure metadata: no leaf is a concrete array
    for leaf in jax.tree.leaves(specs,
                                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_vlm_input_specs_have_pixel_embeds():
    cfg = get_config("llava_next_34b")
    model = DecoderLM(cfg)
    specs = model.input_specs(INPUT_SHAPES["train_4k"])
    assert specs["pixel_embeds"].shape == (256, cfg.frontend_tokens,
                                           cfg.frontend_dim)


@settings(deadline=None)
@given(chunk=st.sampled_from([2, 4, 8]), s=st.sampled_from([16, 32]))
def test_chunked_wkv_scan_property(chunk, s):
    from repro.models.rwkv import _wkv_scan
    rng = np.random.RandomState(chunk * 100 + s)
    b, h, hd = 2, 2, 4
    r, k, v = (jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.rand(b, s, h, hd) * 0.5 + 0.4, jnp.float32)
    u = jnp.asarray(rng.randn(h, hd), jnp.float32)
    st0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    st_a, y_a = _wkv_scan(r, k, v, w, u, st0, chunk=0)
    st_b, y_b = _wkv_scan(r, k, v, w, u, st0, chunk=chunk)
    np.testing.assert_allclose(y_a, y_b, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(st_a, st_b, rtol=1e-5, atol=1e-5)


def test_chunked_ssm_matches_plain():
    import dataclasses
    cfg0 = get_config("hymba_1_5b").reduced()
    m0 = DecoderLM(cfg0)
    m1 = DecoderLM(dataclasses.replace(cfg0, scan_chunk=8))
    params = m0.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg0.vocab, (2, 16)),
                                   jnp.int32)}
    l0, _ = m0.forward(params, batch)
    l1, _ = m1.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), rtol=1e-4,
                               atol=1e-4)


def test_chunked_scan_grads_match():
    """remat'd chunked scan must give the same gradients."""
    import dataclasses
    cfg0 = get_config("rwkv6_7b").reduced(head_dim=32, d_ff=128, vocab=256)
    m0 = DecoderLM(cfg0)
    m1 = DecoderLM(dataclasses.replace(cfg0, scan_chunk=8))
    params = m0.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(1)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg0.vocab, (2, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg0.vocab, (2, 16)),
                                   jnp.int32)}
    g0 = jax.grad(lambda p: m0.loss(p, None, batch)[0])(params)
    g1 = jax.grad(lambda p: m1.loss(p, None, batch)[0])(params)

    def close(a, b):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        assert np.abs(a - b).max() <= 1e-3 * (np.abs(a).max() + 1e-6)

    jax.tree.map(close, g0, g1)


def test_tp_aligned_spec_shapes():
    """tp_shards shrinks factor blocks to shard width on the sharded side."""
    import dataclasses
    cfg = dataclasses.replace(get_config("llama3_2_1b"), tp_shards=16)
    m = DecoderLM(cfg)
    # mlp_down: a-side is d_ff=8192, sharded -> blocks of 512
    spec = m.specs["mlp_down"]
    assert spec.a_dim == 512
    assert spec.a_shape(8192) == (16, 512, 512)
    # wq g-side: h*hd = 2048 -> 128-wide blocks
    assert m.specs["attn_wq"].g_dim == 128
    # wk g-side: kv*hd = 512 -> 512/16=32 < min_block: NOT aligned
    assert m.specs["attn_wk"].g_dim == cfg.kfac_max_dim