"""Oracle tests for the dummy-cotangent curvature capture.

The reference computes per-token gradients explicitly (vmap of per-example
grads) and forms the factor sums by hand; the tagged sites must reproduce
both the ordinary parameter gradients and the raw factor sums exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kfac, tagging
from repro.core.tagging import FactorSpec


def _mlp_loss(params, fstats, x, y):
    """2-layer tagged MLP, MSE loss averaged over batch."""
    h = tagging.dense_site(x, params["w1"], fstats["l1"] if fstats else None,
                           FactorSpec(max_dim=64))
    h = jnp.tanh(h)
    o = tagging.dense_site(h, params["w2"], fstats["l2"] if fstats else None,
                           FactorSpec(max_dim=64))
    return jnp.mean((o - y) ** 2)


def _make_mlp(seed=0, n=16, d_in=5, d_h=7, d_out=3):
    rng = np.random.RandomState(seed)
    params = {"w1": jnp.asarray(rng.randn(d_in, d_h), jnp.float32),
              "w2": jnp.asarray(rng.randn(d_h, d_out), jnp.float32)}
    x = jnp.asarray(rng.randn(n, d_in), jnp.float32)
    y = jnp.asarray(rng.randn(n, d_out), jnp.float32)
    fstats = {"l1": tagging.make_stats(FactorSpec(max_dim=64), d_in, d_h),
              "l2": tagging.make_stats(FactorSpec(max_dim=64), d_h, d_out)}
    return params, fstats, x, y


def test_dense_site_forward_equals_matmul():
    params, fstats, x, y = _make_mlp()
    l_tagged = _mlp_loss(params, fstats, x, y)
    l_plain = _mlp_loss(params, None, x, y)
    np.testing.assert_allclose(l_tagged, l_plain, rtol=1e-6)


def test_dense_site_param_grads_unchanged():
    params, fstats, x, y = _make_mlp()
    g_tagged = jax.grad(_mlp_loss)(params, fstats, x, y)
    g_plain = jax.grad(_mlp_loss)(params, None, x, y)
    for k in params:
        np.testing.assert_allclose(g_tagged[k], g_plain[k], rtol=1e-5, atol=1e-6)


def test_dense_site_factor_sums_match_explicit():
    params, fstats, x, y = _make_mlp()
    gp, gs = jax.grad(_mlp_loss, argnums=(0, 1))(params, fstats, x, y)

    # A factors: raw sums of layer inputs
    a1 = np.asarray(x).T @ np.asarray(x)
    h = np.tanh(np.asarray(x) @ np.asarray(params["w1"]))
    a2 = h.T @ h
    np.testing.assert_allclose(gs["l1"]["a"][0], a1, rtol=1e-4)
    np.testing.assert_allclose(gs["l2"]["a"][0], a2, rtol=1e-4)

    # G factors: per-token grads w.r.t. layer outputs, computed via probes
    def probe_loss(probes, params, x, y):
        h = jnp.tanh(x @ params["w1"] + probes["s1"])
        o = h @ params["w2"] + probes["s2"]
        return jnp.mean((o - y) ** 2)

    probes = {"s1": jnp.zeros((x.shape[0], 7)), "s2": jnp.zeros((x.shape[0], 3))}
    pg = jax.grad(probe_loss)(probes, params, x, y)
    g1 = np.asarray(pg["s1"]).T @ np.asarray(pg["s1"])
    g2 = np.asarray(pg["s2"]).T @ np.asarray(pg["s2"])
    np.testing.assert_allclose(gs["l1"]["g"][0], g1, rtol=1e-4, atol=1e-8)
    np.testing.assert_allclose(gs["l2"]["g"][0], g2, rtol=1e-4, atol=1e-8)


def test_dense_site_blocked_factors():
    """max_dim smaller than d_in -> block-diagonal pieces of the full factor."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(11, 6), jnp.float32)
    w = jnp.asarray(rng.randn(6, 4), jnp.float32)
    spec = FactorSpec(max_dim=3)
    stats = tagging.make_stats(spec, 6, 4)

    def loss(w, s):
        return jnp.sum(tagging.dense_site(x, w, s, spec) ** 2)

    gs = jax.grad(loss, argnums=1)(w, stats)
    full = np.asarray(x).T @ np.asarray(x)
    np.testing.assert_allclose(gs["a"][0], full[:3, :3], rtol=1e-4)
    np.testing.assert_allclose(gs["a"][1], full[3:, 3:], rtol=1e-4)


def test_dense_site_diag_g():
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(9, 4), jnp.float32)
    w = jnp.asarray(rng.randn(4, 5), jnp.float32)
    spec = FactorSpec(g_kind="diag", max_dim=64)
    stats = tagging.make_stats(spec, 4, 5)

    def loss(w, s):
        return jnp.sum(jnp.sin(tagging.dense_site(x, w, s, spec)))

    gs = jax.grad(loss, argnums=1)(w, stats)
    gy = np.cos(np.asarray(x) @ np.asarray(w))   # dL/ds
    np.testing.assert_allclose(gs["g"], (gy ** 2).sum(0), rtol=1e-4)


def test_grouped_site_per_expert_factors():
    rng = np.random.RandomState(5)
    E, n, d, f = 3, 8, 4, 6
    x = jnp.asarray(rng.randn(E, n, d), jnp.float32)
    w = jnp.asarray(rng.randn(E, d, f), jnp.float32)
    spec = FactorSpec(max_dim=64)
    stats = {"a": jnp.zeros((E, 1, d, d)), "g": jnp.zeros((E, 1, f, f))}

    def loss(w, s):
        return jnp.sum(tagging.grouped_dense_site(x, w, s, spec) ** 2)

    (gw, gs) = jax.grad(loss, argnums=(0, 1))(w, stats)
    for e in range(E):
        xe = np.asarray(x[e])
        np.testing.assert_allclose(gs["a"][e, 0], xe.T @ xe, rtol=1e-4)
        # grads match plain einsum
    gw_plain = jax.grad(lambda w: jnp.sum(jnp.einsum("end,edf->enf", x, w) ** 2))(w)
    np.testing.assert_allclose(gw, gw_plain, rtol=1e-4)


def test_bias_site():
    rng = np.random.RandomState(6)
    x = jnp.asarray(rng.randn(7, 3), jnp.float32)
    b = jnp.asarray(rng.randn(3), jnp.float32)
    stats = tagging.make_bias_stats(3)

    def loss(b, s):
        return jnp.sum(jnp.cos(tagging.bias_site(x, b, s)))

    (gb, gs) = jax.grad(loss, argnums=(0, 1))(b, stats)
    gy = -np.sin(np.asarray(x) + np.asarray(b))
    np.testing.assert_allclose(gb, gy.sum(0), rtol=1e-4)
    np.testing.assert_allclose(gs["d"], (gy ** 2).sum(0), rtol=1e-4)


def test_scale_bias_site_tokenwise():
    rng = np.random.RandomState(7)
    xh = jnp.asarray(rng.randn(10, 4), jnp.float32)
    gamma = jnp.asarray(rng.rand(4) + 0.5, jnp.float32)
    beta = jnp.asarray(rng.randn(4), jnp.float32)
    stats = tagging.make_scale_bias_stats(4)

    def loss(gamma, beta, s):
        return jnp.sum(jnp.tanh(tagging.scale_bias_site(xh, gamma, beta, s)))

    (gg, gb, gs) = jax.grad(loss, argnums=(0, 1, 2))(gamma, beta, stats)
    y = np.asarray(xh) * np.asarray(gamma) + np.asarray(beta)
    gy = 1 - np.tanh(y) ** 2
    u = gy * np.asarray(xh)
    np.testing.assert_allclose(gg, u.sum(0), rtol=1e-4)
    np.testing.assert_allclose(gb, gy.sum(0), rtol=1e-4)
    np.testing.assert_allclose(gs["uw"][:, 0], (u ** 2).sum(0), rtol=1e-4)
    np.testing.assert_allclose(gs["uw"][:, 1], (u * gy).sum(0), rtol=1e-4)
    np.testing.assert_allclose(gs["uw"][:, 2], (gy ** 2).sum(0), rtol=1e-4)


def test_scale_bias_site_spatial_sum():
    """Conv-style BN: per-sample grads sum H,W before the outer product."""
    rng = np.random.RandomState(8)
    B, H, W, C = 3, 2, 2, 4
    xh = jnp.asarray(rng.randn(B, H, W, C), jnp.float32)
    gamma = jnp.ones(C)
    beta = jnp.zeros(C)
    stats = tagging.make_scale_bias_stats(C)

    def loss(gamma, beta, s):
        return jnp.sum(tagging.scale_bias_site(xh, gamma, beta, s, spatial=2) ** 2)

    gs = jax.grad(loss, argnums=2)(gamma, beta, stats)
    gy = 2 * np.asarray(xh)                       # dL/dy
    u = (gy * np.asarray(xh)).sum((1, 2))         # (B, C) per-sample
    v = gy.sum((1, 2))
    np.testing.assert_allclose(gs["uw"][:, 0], (u ** 2).sum(0), rtol=1e-4)
    np.testing.assert_allclose(gs["uw"][:, 2], (v ** 2).sum(0), rtol=1e-4)


def test_embed_site():
    rng = np.random.RandomState(9)
    V, d = 11, 6
    table = jnp.asarray(rng.randn(V, d), jnp.float32)
    ids = jnp.asarray([1, 3, 3, 7], jnp.int32)
    spec = FactorSpec(a_kind="diag", max_dim=64)
    stats = tagging.make_embed_stats(V, d, spec)

    def loss(table, s):
        return jnp.sum(tagging.embed_site(ids, table, s, spec) ** 2)

    (gt, gs) = jax.grad(loss, argnums=(0, 1))(table, stats)
    counts = np.bincount(np.asarray(ids), minlength=V).astype(np.float32)
    np.testing.assert_allclose(gs["a"], counts)
    emb = np.asarray(table)[np.asarray(ids)]
    gy = 2 * emb
    np.testing.assert_allclose(gs["g"][0], gy.T @ gy, rtol=1e-4)
    gt_plain = jax.grad(lambda t: jnp.sum(jnp.take(t, ids, axis=0) ** 2))(table)
    np.testing.assert_allclose(gt, gt_plain, rtol=1e-5)


def test_conv_site_matches_conv_and_factors():
    rng = np.random.RandomState(10)
    B, H, W, Cin, Cout, k = 2, 5, 5, 3, 4, 3
    x = jnp.asarray(rng.randn(B, H, W, Cin), jnp.float32)
    w = jnp.asarray(rng.randn(k, k, Cin, Cout), jnp.float32)
    spec = FactorSpec(max_dim=64)
    stats = tagging.make_stats(spec, Cin * k * k, Cout)

    y = tagging.conv_site(x, w, stats, spec=spec)
    y_ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-5)

    def loss(w, s):
        return jnp.sum(tagging.conv_site(x, w, s, spec=spec) ** 2)

    (gw, gs) = jax.grad(loss, argnums=(0, 1))(w, stats)
    gw_ref = jax.grad(lambda w: jnp.sum(jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) ** 2))(w)
    np.testing.assert_allclose(gw, gw_ref, rtol=1e-3, atol=1e-4)
    # A factor: im2col patch second moment
    patches = jax.lax.conv_general_dilated_patches(
        x, (k, k), (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    p2d = np.asarray(patches).reshape(-1, Cin * k * k)
    np.testing.assert_allclose(gs["a"][0], p2d.T @ p2d, rtol=1e-3)


def test_capture_works_under_scan():
    """Stacked layers via lax.scan: factor cotangents stack to (L, ...)."""
    rng = np.random.RandomState(11)
    L, n, d = 4, 6, 5
    ws = jnp.asarray(rng.randn(L, d, d) * 0.3, jnp.float32)
    x = jnp.asarray(rng.randn(n, d), jnp.float32)
    spec = FactorSpec(max_dim=64)
    fstats = {"a": jnp.zeros((L, 1, d, d)), "g": jnp.zeros((L, 1, d, d))}

    def loss(ws, fs):
        def body(h, xs):
            w, s = xs
            h = jnp.tanh(tagging.dense_site(h, w, s, spec))
            return h, h
        h, acts = jax.lax.scan(body, x, (ws, fs))
        return jnp.sum(h ** 2), acts

    (l, acts), gs = jax.value_and_grad(loss, argnums=1, has_aux=True)(ws, fstats)
    # layer-0 A factor is x^T x; layer-1 A factor is from tanh(x@w0)
    np.testing.assert_allclose(gs["a"][0, 0], np.asarray(x).T @ np.asarray(x),
                               rtol=1e-4)
    h1 = np.tanh(np.asarray(x) @ np.asarray(ws[0]))
    np.testing.assert_allclose(gs["a"][1, 0], h1.T @ h1, rtol=1e-4)
    # no NaNs anywhere
    assert np.isfinite(np.asarray(gs["g"])).all()


def test_capture_composes_with_jit_and_remat():
    params, fstats, x, y = _make_mlp()
    f = jax.jit(jax.grad(jax.remat(_mlp_loss), argnums=(0, 1)))
    gp, gs = f(params, fstats, x, y)
    gp2, gs2 = jax.grad(_mlp_loss, argnums=(0, 1))(params, fstats, x, y)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6),
                 (gp, gs), (gp2, gs2))
