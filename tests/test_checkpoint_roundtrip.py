"""Checkpoint round-trip under non-f32 factor history (ISSUE-3 satellite):
save/restore a 5-step SP-NGD run mid-stream with bf16 and fp8 history and
assert BIT-IDENTICAL continuation vs the uninterrupted run — params,
velocity, curvature history (incl. fp8 payloads + scales) and the host-side
IntervalController state all have to survive the .npz round trip exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController

from test_ngd_optimizer import (loss_fn, fstats_fn, counts_fn, INFOS, _data,
                                D_IN, D_H, D_OUT)

STEPS, BREAK_AT = 5, 3


def _make(cfg):
    rng = np.random.RandomState(12)
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, D_OUT) * 0.4, jnp.float32)}
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn, cfg)
    ctrl = IntervalController(opt.stat_names(), alpha=0.1,
                              bytes_per_stat=opt.stat_bytes())
    return params, opt, opt.init(params), ctrl


def _advance(opt, ctrl, params, state, t):
    batch = _data(seed=t)
    flags = ctrl.flags(t)
    if any(flags.values()):
        jf = {k: jnp.asarray(v) for k, v in flags.items()}
        params, state, m = jax.jit(opt.step)(params, state, batch, jf,
                                             1e-3, 0.1, 0.9)
        ctrl.update(t, flags, {k: (float(v[0]), float(v[1]))
                               for k, v in m["sims"].items()})
    else:
        params, state, m = jax.jit(opt.step_fast)(params, state, batch,
                                                  1e-3, 0.1, 0.9)
        ctrl.update(t, flags, {})
    return params, state


def _assert_trees_bitwise_equal(a, b):
    def eq(x, y):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(
            x.view(np.dtype(f"u{x.dtype.itemsize}")),
            y.view(np.dtype(f"u{y.dtype.itemsize}")))
    jax.tree.map(eq, a, b)


@pytest.mark.parametrize("factor_dtype", [jnp.bfloat16, "fp8_e4m3"],
                         ids=["bf16", "fp8_e4m3"])
def test_checkpoint_roundtrip_continuation(tmp_path, factor_dtype):
    cfg = NGDConfig(damping=1e-3, factor_dtype=factor_dtype)

    # uninterrupted run
    params, opt, state, ctrl = _make(cfg)
    for t in range(1, STEPS + 1):
        params, state = _advance(opt, ctrl, params, state, t)

    # interrupted run: save at BREAK_AT, restore into fresh objects, resume
    p2, opt2, s2, c2 = _make(cfg)
    for t in range(1, BREAK_AT + 1):
        p2, s2 = _advance(opt2, c2, p2, s2, t)
    save_checkpoint(str(tmp_path), BREAK_AT, p2, s2, c2.state_dict())

    r = restore_checkpoint(str(tmp_path))
    assert r["step"] == BREAK_AT
    p3, s3 = r["params"], r["opt_state"]
    _assert_trees_bitwise_equal(p3, p2)        # the round trip itself
    _assert_trees_bitwise_equal(s3, s2)
    c3 = IntervalController.from_state_dict(r["controller"])
    assert c3.state_dict() == c2.state_dict()
    _, opt3, _, _ = _make(cfg)
    for t in range(BREAK_AT + 1, STEPS + 1):
        p3, s3 = _advance(opt3, c3, p3, s3, t)

    # continuation must be bit-identical to the uninterrupted run
    _assert_trees_bitwise_equal(p3, params)
    _assert_trees_bitwise_equal(s3, state)
    assert c3.state_dict() == ctrl.state_dict()


def test_checkpoint_roundtrip_double_buffer(tmp_path):
    """ISSUE-7: the double-buffered inverse state (active + staged
    preconditioners) must survive a mid-interval save/restore and continue
    bit-identically — BREAK_AT=3 lands between a refresh and its activation
    consumer, so both buffers genuinely differ at the break."""
    cfg = NGDConfig(damping=1e-3, double_buffer=True)

    params, opt, state, ctrl = _make(cfg)
    for t in range(1, STEPS + 1):
        params, state = _advance(opt, ctrl, params, state, t)

    p2, opt2, s2, c2 = _make(cfg)
    for t in range(1, BREAK_AT + 1):
        p2, s2 = _advance(opt2, c2, p2, s2, t)
    # both buffers are in the saved tree
    for fam in s2["curv"]:
        assert "precond_next" in s2["curv"][fam]
    save_checkpoint(str(tmp_path), BREAK_AT, p2, s2, c2.state_dict())

    r = restore_checkpoint(str(tmp_path))
    p3, s3 = r["params"], opt2.upgrade_state(r["opt_state"])
    _assert_trees_bitwise_equal(s3, s2)        # same layout: passthrough
    c3 = IntervalController.from_state_dict(r["controller"])
    _, opt3, _, _ = _make(cfg)
    for t in range(BREAK_AT + 1, STEPS + 1):
        p3, s3 = _advance(opt3, c3, p3, s3, t)
    _assert_trees_bitwise_equal(p3, params)
    _assert_trees_bitwise_equal(s3, state)
    assert c3.state_dict() == ctrl.state_dict()


def test_checkpoint_roundtrip_mid_pipeline(tmp_path):
    """ISSUE-10: a checkpoint taken MID-DRAIN of the chunked refresh
    pipeline (cursor between capture and flip, raw store + valid latches
    populated) must resume bit-identically. With refresh_chunks=2 and a
    capture-every-3-steps cadence, BREAK_AT=3 lands at cursor=2 — both
    chunks processed, the flip still pending — so the resumed run's very
    first step is the activation the interrupted run never applied."""
    k = 2
    cfg = NGDConfig(damping=1e-3, double_buffer=True, refresh_chunks=k)

    def advance(opt, ctrl, params, state, t):
        # manual cadence: capture at t=1, 4, ...; fast (drain) otherwise
        on = (t % (k + 1) == 1)
        flags = {n: on for n in opt.stat_names()}
        if on:
            jf = {n: jnp.asarray(True) for n in opt.stat_names()}
            params, state, m = jax.jit(opt.step)(params, state, _data(seed=t),
                                                 jf, 1e-3, 0.1, 0.9)
            ctrl.update(t, flags, {n: (float(v[0]), float(v[1]))
                                   for n, v in m["sims"].items()})
        else:
            params, state, m = jax.jit(opt.step_fast)(params, state,
                                                      _data(seed=t),
                                                      1e-3, 0.1, 0.9)
            ctrl.update(t, flags, {})
        return params, state

    def make():
        params, opt, state, _ = _make(cfg)
        ctrl = IntervalController(opt.stat_names(), alpha=0.1,
                                  min_interval=k + 1,
                                  bytes_per_stat=opt.stat_bytes())
        return params, opt, state, ctrl

    params, opt, state, ctrl = make()
    for t in range(1, STEPS + 1):
        params, state = advance(opt, ctrl, params, state, t)

    p2, opt2, s2, c2 = make()
    for t in range(1, BREAK_AT + 1):
        p2, s2 = advance(opt2, c2, p2, s2, t)
    assert int(s2["pipeline"]["cursor"]) == k          # mid-drain, pre-flip
    assert all(bool(v) for v in jax.tree.leaves(s2["pipeline"]["valid"]))
    save_checkpoint(str(tmp_path), BREAK_AT, p2, s2, c2.state_dict())

    r = restore_checkpoint(str(tmp_path))
    _, opt3, _, _ = make()
    p3, s3 = r["params"], opt3.upgrade_state(r["opt_state"])
    _assert_trees_bitwise_equal(s3, s2)        # same layout: passthrough
    c3 = IntervalController.from_state_dict(r["controller"])
    assert c3.min_interval == k + 1
    for t in range(BREAK_AT + 1, STEPS + 1):
        p3, s3 = advance(opt3, c3, p3, s3, t)

    _assert_trees_bitwise_equal(p3, params)
    _assert_trees_bitwise_equal(s3, state)
    assert c3.state_dict() == ctrl.state_dict()


def test_pre_pr7_checkpoint_single_buffer_fallback(tmp_path):
    """A pre-PR-7 checkpoint (no staged buffer, no gather ledger) must load
    into a double-buffered run: ``upgrade_state`` seeds the staged buffer
    from the active one (first activation is a no-op) and the controller
    resumes with the gather ledger at zero."""
    sb_cfg = NGDConfig(damping=1e-3)
    params, opt, state, ctrl = _make(sb_cfg)
    for t in range(1, BREAK_AT + 1):
        params, state = _advance(opt, ctrl, params, state, t)
    # strip the PR-7 ledger fields to get a byte-faithful old checkpoint
    cs = ctrl.state_dict()
    del cs["total_gather_bytes"], cs["dense_gather_bytes"]
    for st in cs["stats"].values():
        del st["gather_bytes_per_refresh"]
    save_checkpoint(str(tmp_path), BREAK_AT, params, state, cs)

    r = restore_checkpoint(str(tmp_path))
    db_cfg = NGDConfig(damping=1e-3, double_buffer=True)
    _, opt2, _, _ = _make(db_cfg)
    s2 = opt2.upgrade_state(r["opt_state"])
    for fam in s2["curv"]:
        assert "precond_next" in s2["curv"][fam]
        _assert_trees_bitwise_equal(s2["curv"][fam]["precond_next"],
                                    s2["curv"][fam]["precond"])
    c2 = IntervalController.from_state_dict(r["controller"])
    assert c2.total_gather_bytes == 0
    p2 = r["params"]
    for t in range(BREAK_AT + 1, STEPS + 1):
        p2, s2 = _advance(opt2, c2, p2, s2, t)
    for leaf in jax.tree.leaves(p2):
        assert np.isfinite(np.asarray(leaf)).all()
