"""Chunked refresh pipeline unit tests (ISSUE-10 tentpole).

The state machine under test (repro.core.pipeline.RefreshPipeline):

  capture step   Stage-2/3 + history shift run inline, the normalized
                 statistics land in the pipeline's raw store, cursor <- 0.
                 NO inversions run on this step.
  K drain steps  fast step i fuses chunk i's Stage-4 inversions + gathers
                 into its program, writing into precond_next.
  flip step      cursor == K: precond_next -> precond (the double-buffer
                 activation contract), cursor parks at K+1 (idle).

So a refresh captured at step t activates at step t + K + 1, vs t + 1 for
the inline double buffer — the pinned ``refresh_inflight`` sequence is
K+1 on the capture AND the first drain step (the capture does not advance
the cursor), counting down to 1 on the flip step, 0 when idle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ngd import NGDConfig, SPNGD
from repro.core.pipeline import RefreshPipeline
from repro.core.stale import IntervalController

from test_ngd_optimizer import (loss_fn, fstats_fn, counts_fn, INFOS, _data,
                                D_IN, D_H)

K = 2
ARGS = (1e-3, 0.1, 0.0)          # lam, lr, mom (mom off: no velocity mixing)


def _opt(**kw):
    rng = np.random.RandomState(7)
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, 4) * 0.4, jnp.float32)}
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn,
                NGDConfig(damping=1e-3, **kw))
    return opt, params, opt.init(params), _data()


def _bitwise_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="double_buffer"):
        _opt(refresh_chunks=2)
    with pytest.raises(ValueError, match="inverse_info"):
        _opt(refresh_chunks=2, double_buffer=True, inverse_info=True)
    opt, *_ = _opt(double_buffer=True)
    assert opt.pipeline is None                  # K == 1: no pipeline
    with pytest.raises(ValueError):
        RefreshPipeline(opt, 0)


def test_schedule_partitions_every_stat_once():
    opt, *_ = _opt(double_buffer=True, refresh_chunks=K)
    pipe = opt.pipeline
    assert pipe.chunks == K
    units = [u for chunk in pipe.schedule for u in chunk]
    assert len(units) == len(set(units))         # disjoint
    assert {f"{fam}.{key}" for fam, key in units} == set(opt.stat_names())
    # K beyond the stat count is legal: trailing chunks are empty no-ops
    big = RefreshPipeline(opt, 64)
    big_units = [u for chunk in big.schedule for u in chunk]
    assert sorted(big_units) == sorted(units)
    assert any(not chunk for chunk in big.schedule)


# ---------------------------------------------------------------------------
# the state machine: capture -> drain -> flip -> idle
# ---------------------------------------------------------------------------

def test_activation_timing_and_inflight_sequence():
    """The capture leaves the active preconditioner untouched; it stays
    bit-frozen through all K drain steps and flips exactly at step K+1 to
    the same inverses the inline double-buffer refresh stages in one step
    (identical math, chunked schedule)."""
    opt, params, state, batch = _opt(double_buffer=True, refresh_chunks=K)
    opt_db, _, state_db, _ = _opt(double_buffer=True)
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    init_pc = state["curv"]

    # inline reference: stages the fresh inverses at the capture step
    _, s_db, _ = jax.jit(opt_db.step)(params, state_db, batch, flags, *ARGS)

    p, s, m = jax.jit(opt.step)(params, state, batch, flags, *ARGS)
    assert int(m["refresh_inflight"]) == K + 1
    assert int(s["pipeline"]["cursor"]) == 0
    for fam in s["curv"]:
        assert _bitwise_equal(s["curv"][fam]["precond"],
                              init_pc[fam]["precond"])

    seen = []
    for i in range(K + 2):
        p, s, m = jax.jit(opt.step_fast)(p, s, batch, *ARGS)
        seen.append(int(m["refresh_inflight"]))
        if i < K:      # drain steps: the active buffer stays bit-frozen
            for fam in s["curv"]:
                assert _bitwise_equal(s["curv"][fam]["precond"],
                                      init_pc[fam]["precond"]), i
    # K+1 again on the first drain step (the capture did not advance the
    # cursor), counting down to 1 on the flip/activation step, then idle
    assert seen == list(range(K + 1, 0, -1)) + [0]
    assert int(s["pipeline"]["cursor"]) == K + 1

    # post-flip: active == staged == the inline refresh's staged inverses
    for fam in s["curv"]:
        assert _bitwise_equal(s["curv"][fam]["precond"],
                              s["curv"][fam]["precond_next"])
        for key in s["curv"][fam]["precond"]:
            np.testing.assert_allclose(
                np.asarray(s["curv"][fam]["precond"][key]),
                np.asarray(s_db["curv"][fam]["precond_next"][key]),
                rtol=1e-5, atol=1e-6, err_msg=f"{fam}.{key}")

    # idle steps leave the whole curvature tree bit-identical
    _, s2, m2 = jax.jit(opt.step_fast)(p, s, batch, *ARGS)
    assert int(m2["refresh_inflight"]) == 0
    assert _bitwise_equal(s2["curv"], s["curv"])
    assert _bitwise_equal(s2["pipeline"], s["pipeline"])


def test_mid_drain_recapture_restarts_cleanly():
    """A capture arriving before the previous drain finished (offset
    per-stat schedules can do this) restarts the pipeline on the NEW
    statistics; the interrupted refresh never activates (its flip was
    pending work that the restart discards — cursor < K means no flip)."""
    opt, params, state, batch = _opt(double_buffer=True, refresh_chunks=K)
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    init_pc = state["curv"]

    p, s, _ = jax.jit(opt.step)(params, state, batch, flags, *ARGS)
    p, s, _ = jax.jit(opt.step_fast)(p, s, batch, *ARGS)   # chunk 0 only
    p, s, m = jax.jit(opt.step)(p, s, batch, flags, *ARGS)  # recapture
    assert int(m["refresh_inflight"]) == K + 1
    assert int(s["pipeline"]["cursor"]) == 0
    for fam in s["curv"]:       # the interrupted refresh never flipped
        assert _bitwise_equal(s["curv"][fam]["precond"],
                              init_pc[fam]["precond"])
    for _ in range(K + 1):      # full drain of the second capture
        p, s, _ = jax.jit(opt.step_fast)(p, s, batch, *ARGS)
    changed = any(
        not _bitwise_equal(s["curv"][fam]["precond"],
                           init_pc[fam]["precond"]) for fam in s["curv"])
    assert changed              # the second refresh did activate
    for leaf in jax.tree.leaves(s):
        assert np.isfinite(np.asarray(leaf)).all()


def test_upgrade_state_pipeline_layouts():
    opt_db, _, state_db, _ = _opt(double_buffer=True)
    opt_pl, _, state_pl, _ = _opt(double_buffer=True, refresh_chunks=K)
    # pre-pipeline checkpoint -> pipelined run: fresh idle pipeline seeded
    up = opt_pl.upgrade_state(state_db)
    assert jax.tree.structure(up) == jax.tree.structure(state_pl)
    assert int(up["pipeline"]["cursor"]) == K + 1          # idle, no flip
    assert not any(bool(v) for v in jax.tree.leaves(up["pipeline"]["valid"]))
    # pipelined checkpoint -> inline run: pipeline state dropped
    down = opt_db.upgrade_state(state_pl)
    assert jax.tree.structure(down) == jax.tree.structure(state_db)
    # same-layout passthrough
    assert _bitwise_equal(opt_pl.upgrade_state(state_pl), state_pl)


# ---------------------------------------------------------------------------
# the controller floor that keeps captures from outrunning the drain
# ---------------------------------------------------------------------------

def test_interval_controller_min_interval_floor():
    ctrl = IntervalController(["x"], alpha=0.1, min_interval=K + 1)
    # a shrink that Algorithm 2 would drive to 1 is clamped to the floor
    ctrl.update(1, {"x": True}, {"x": (0.9, 0.9)})
    st = ctrl.stats["x"]
    assert st.delta == K + 1 and st.t_next == 1 + (K + 1)
    # growth proceeds from the clamped value (the Fibonacci recurrence
    # simply starts higher; it is not re-floored away)
    ctrl.update(st.t_next, {"x": True}, {"x": (0.0, 0.0)})
    assert ctrl.stats["x"].delta == (K + 1) + 1
    # serialization round-trips the floor; old checkpoints default to 1
    rt = IntervalController.from_state_dict(ctrl.state_dict())
    assert rt.min_interval == K + 1
    legacy = ctrl.state_dict()
    del legacy["min_interval"]
    assert IntervalController.from_state_dict(legacy).min_interval == 1


def test_chunk_names_and_costs():
    opt, *_ = _opt(double_buffer=True, refresh_chunks=K)
    pipe = opt.pipeline
    names = [n for i in range(K) for n in pipe.chunk_names(i)]
    assert sorted(names) == sorted(opt.stat_names())
    assert len(pipe.loads) == K and all(l > 0 for l in pipe.loads)
