"""Stage-4 inversion test harness: blocked Newton-Schulz vs eigh.

The Newton-Schulz ``damped_inverse`` backend is the one kernel whose
numerics depend on CONDITIONING, not just shape — K-FAC at large batch
degrades exactly when factor conditioning drifts — so parity smoke is not
enough. Four layers of coverage:

* conditioning grid — parametrized spectra (log-uniform condition numbers
  1e0..1e8, near-rank-deficient, identity, tiny/huge scale) x damping
  {1e-8, 1e-3, 1e-1} x dtype {f32, bf16-in/f32-accum}: the dispatched
  inverse must stay within tolerance of the eigh oracle EVERYWHERE
  (converged blocks by contraction, pathological blocks by the eigh
  fallback), and the fallback must demonstrably trigger — and return the
  bit-exact eigh result — for the known-ill-conditioned combinations.
* op level — ref (jnp iteration) vs pallas (VMEM-resident kernel) parity
  incl. blocked layouts with leading layer/expert axes, and the
  ``M @ X ~= I`` fixed-point oracle.
* dispatch unification — a lookup spy proving both Stage-4 call sites
  (``ngd._damped_inv`` and ``kfac.damped_factor_inverses``) reach the
  inversion through ``dispatch.damped_inverse`` with the pallas impl and
  never recompute through the ref table entry on the pallas path.
* e2e — 20-step ref-eigh vs pallas-Newton-Schulz train parity (jit +
  shard_map schedules) and the fp8 ``factor_dtype`` x ``newton_schulz``
  cross-product smoke (NS consuming PR 3's dequantized stale history).
"""
import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kfac
from repro.kernels import dispatch, ops

NB, B = 2, 16          # blocked layout used across the grid (pads to the
                       # kernel's 128-lane tile, exercising the pad path)


def _seed(*key) -> int:
    """Process-independent seed (python's hash() is PYTHONHASHSEED-salted,
    which would unpin the empirically-pinned grid statuses below)."""
    return zlib.crc32(repr(key).encode())


def _spd_from_spectrum(spectrum, nb=NB, b=B, seed=0, lead=()):
    """SPD blocked factor with a prescribed spectrum per block."""
    rng = np.random.RandomState(seed)
    n = int(np.prod(lead, dtype=int)) * nb
    lam = np.asarray(spectrum(b), np.float64)
    qs = np.linalg.qr(rng.randn(n, b, b))[0]
    f = np.einsum("kab,kb,kcb->kac", qs, np.broadcast_to(lam, (n, b)), qs)
    return jnp.asarray(f.reshape(lead + (nb, b, b)), jnp.float32)


def _gram_from_spectrum(spectrum, nb=NB, b=B, seed=0):
    """bf16-in/f32-accum factor: the framework's actual statistics path.

    Factors are Grams of token matrices (A = X^T X with bf16 X, f32
    accumulation — kfac.factor_sum's contract), so they are PSD BY
    CONSTRUCTION no matter how X quantizes; this is what "bf16" means for
    Stage-4 inputs. (Quantizing a dense SPD matrix itself to bf16 instead
    makes small eigenvalues go negative — a different, ill-posed problem
    that the SPD guard in dispatch handles, tested separately.) The
    realized spectrum is ``spectrum`` floored at bf16 quantization of the
    token matrix (~(2^-8 ||X||)^2)."""
    rng = np.random.RandomState(seed)
    lam = np.asarray(spectrum(b), np.float64)
    out = []
    for k in range(nb):
        q = np.linalg.qr(rng.randn(b, b))[0]
        r = np.linalg.qr(rng.randn(2 * b, b))[0]      # orthonormal columns
        x = jnp.asarray(r @ np.diag(np.sqrt(lam)) @ q.T, jnp.bfloat16)
        out.append(jnp.einsum("na,nb->ab", x, x,
                              preferred_element_type=jnp.float32))
    return jnp.stack(out)


def _logspec(cond):
    return lambda b: np.logspace(0.0, -np.log10(max(cond, 1.0)), b)


SPECTRA = {
    "cond_1e0": _logspec(1e0),
    "cond_1e2": _logspec(1e2),
    "cond_1e4": _logspec(1e4),
    "cond_1e6": _logspec(1e6),
    "cond_1e8": _logspec(1e8),
    # exact zero eigenvalues: only the damping keeps it invertible
    "near_rank_def": lambda b: np.r_[np.ones(b - b // 4), np.zeros(b // 4)],
    "identity": lambda b: np.ones(b),
    # the init bound X0 = M / (||M||_1 ||M||_inf) is scale-invariant; these
    # catch any fixed-magnitude assumption (e.g. identity-valued padding)
    "tiny_scale": lambda b: 1e-12 * np.logspace(0.0, -2.0, b),
    "huge_scale": lambda b: 1e12 * np.logspace(0.0, -2.0, b),
}

# combinations whose DAMPED condition number exceeds what ns_iters=40 can
# contract in f32 (the 2^k doubling only bites after k ~ log2 of the
# squared condition number): the eigh fallback MUST carry exactly these.
# Note tiny/huge scale are absent — the norm-based init is scale-invariant,
# and with damping >= 1e-3 every spectrum here damps to kappa <= ~1e3.
# (Statuses pinned empirically; deterministic under the fixed seeds.)
FALLBACK_EXPECTED = {
    "float32": {("cond_1e6", 1e-8), ("cond_1e8", 1e-8),
                ("near_rank_def", 1e-8)},
    "bfloat16": {("cond_1e6", 1e-8), ("cond_1e8", 1e-8),
                 ("near_rank_def", 1e-8)},
}
# every other combination must converge WITHOUT the fallback (so the grid
# can't pass on the strength of eigh alone)
ALL_COMBOS = {(s, d) for s in SPECTRA for d in (1e-8, 1e-3, 1e-1)}


@pytest.mark.parametrize("damping", [1e-8, 1e-3, 1e-1])
@pytest.mark.parametrize("spectrum", sorted(SPECTRA))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conditioning_grid(spectrum, damping, dtype):
    seed = _seed(spectrum, damping)
    if dtype == jnp.bfloat16:
        f = _gram_from_spectrum(SPECTRA[spectrum], seed=seed)
    else:
        f = _spd_from_spectrum(SPECTRA[spectrum], seed=seed)
    d = jnp.asarray(damping, jnp.float32)
    # both legs hand the SAME f32 factor to both methods (the bf16 leg's
    # quantization lives in the statistics construction, per the §5.2
    # contract), so one f32-grade tolerance covers the whole grid
    eigh = dispatch.damped_inverse(f, d, method="eigh", backend="ref")
    assert eigh.dtype == jnp.float32
    ns, info = dispatch.damped_inverse(f, d, method="newton_schulz",
                                       backend="pallas", return_info=True)
    assert ns.dtype == jnp.float32 and np.isfinite(np.asarray(ns)).all()
    conv = np.asarray(info["ns_converged"])

    # the harness contract: whatever route each block took, the result
    # stays within tolerance of the eigh oracle
    scale = np.max(np.abs(np.asarray(eigh)), axis=(-1, -2), keepdims=True)
    err = np.max(np.abs(np.asarray(ns) - np.asarray(eigh)), axis=(-1, -2),
                 keepdims=True)
    assert (err <= 5e-3 * scale).all(), (spectrum, damping, err / scale)

    fallback = FALLBACK_EXPECTED[dtype.__name__]
    if (spectrum, damping) in fallback:
        # the pathological combos must actually exercise the fallback...
        assert not conv.any(), (spectrum, damping, np.asarray(info["ns_res"]))
        # ...and ship the eigh result bit-for-bit (the fallback recomputes
        # with the identical kfac.damped_inverse the oracle above used)
        np.testing.assert_array_equal(np.asarray(ns), np.asarray(eigh))
    else:
        assert conv.all(), (spectrum, damping, np.asarray(info["ns_res"]))


def test_indefinite_block_defers_to_clamped_eigh_semantics():
    """A factor whose small eigenvalues went NEGATIVE (the bf16-accumulation
    noise mode the eigh clamp exists for): Newton-Schulz would happily
    converge to the true inverse of the indefinite matrix, whose negative
    1/lambda directions the framework must not ship — the SPD guard
    (min diag(X) <= 0) must reroute the block to eigh's clamped result."""
    rng = np.random.RandomState(4)
    q = np.linalg.qr(rng.randn(B, B))[0]
    lam = np.r_[np.logspace(0, -2, B - 2), [-4e-3, -1e-2]]
    f = jnp.asarray(q @ np.diag(lam) @ q.T, jnp.float32)[None]
    d = jnp.asarray(1e-3)
    ns, info = dispatch.damped_inverse(f, d, method="newton_schulz",
                                       backend="pallas", return_info=True)
    eigh = dispatch.damped_inverse(f, d, method="eigh", backend="ref")
    assert not np.asarray(info["ns_converged"]).any()
    assert np.isposinf(np.asarray(info["ns_res"])).all()   # guard, not tol
    np.testing.assert_array_equal(np.asarray(ns), np.asarray(eigh))


def test_grid_covers_both_fallback_and_contraction():
    """Meta-guard: each dtype's grid must witness BOTH behaviours (some
    forced fallbacks, mostly contractions) or the harness proves nothing."""
    for dtype, fallback in FALLBACK_EXPECTED.items():
        assert fallback and fallback < ALL_COMBOS, dtype
        assert len(ALL_COMBOS - fallback) > len(fallback), dtype


# ---------------------------------------------------------------------------
# op level: ref iteration vs pallas kernel, fixed-point oracle, layouts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lead", [(), (3,), (2, 2)])
def test_ns_ref_vs_pallas_blocked_layouts(lead):
    f = _spd_from_spectrum(_logspec(1e2), seed=len(lead), lead=lead)
    d = jnp.asarray(1e-3)
    kw = dict(method="newton_schulz", ns_iters=40, ns_tol=1e-4)
    r, ir = dispatch.damped_inverse(f, d, backend="ref", return_info=True,
                                    **kw)
    p, ip = dispatch.damped_inverse(f, d, backend="pallas",
                                    return_info=True, **kw)
    assert r.shape == p.shape == f.shape
    assert ir["ns_res"].shape == ip["ns_res"].shape == f.shape[:-2]
    assert np.asarray(ir["ns_converged"]).all()
    assert np.asarray(ip["ns_converged"]).all()
    np.testing.assert_allclose(np.asarray(r), np.asarray(p),
                               rtol=2e-3, atol=2e-3)


def test_ns_fixed_point_oracle():
    """M @ X must reproduce I to the advertised residual — checked against
    the damped M directly, not against another inverse implementation."""
    f = _spd_from_spectrum(_logspec(1e3), seed=9)
    lam = 1e-3
    x = dispatch.damped_inverse(f, jnp.asarray(lam),
                                method="newton_schulz", backend="pallas")
    m = np.asarray(f, np.float64) + lam * np.eye(B)
    r = np.eye(B) - np.einsum("kab,kbc->kac", m, np.asarray(x, np.float64))
    res = np.sqrt((r ** 2).sum(axis=(-1, -2))) / np.sqrt(B)
    # the kernel's reported residual is rescaled to the unpadded ||I_b||_F
    # normalization (ops.ns_inverse) and upper-bounds this one, so a
    # converged block meets ns_tol in the caller's units
    assert (res <= 1e-4 + 1e-6).all(), res


def test_ns_kernel_rejects_over_vmem_blocks():
    b = ops.NS_KERNEL_MAX_DIM + 128
    with pytest.raises(ValueError, match="NS_KERNEL_MAX_DIM"):
        ops.ns_inverse(jnp.eye(b)[None], iters=kfac.NS_ITERS,
                       tol=kfac.NS_TOL, interpret=True)


def test_ns_pallas_over_vmem_blocks_use_tiled_kernel():
    """A block too large for the resident kernel's VMEM budget routes to
    the two-level tiled kernel (PR 7) — it must invert, not fail, and not
    silently fall back to the jnp reference iteration."""
    b = ops.NS_KERNEL_MAX_DIM + 128
    f = jnp.eye(b)[None] * 2.0
    x = dispatch.damped_inverse(f, jnp.asarray(0.0),
                                method="newton_schulz", backend="pallas",
                                ns_iters=12)
    np.testing.assert_allclose(np.asarray(x), np.eye(b)[None] / 2.0,
                               rtol=1e-4, atol=1e-5)


def test_ns_tiled_1536_matches_eigh_without_fallback(monkeypatch):
    """PR 7 acceptance: a 1536-dim block (1.5x the resident kernel's cap)
    runs through the TILED NS kernel — zero jnp-reference fallbacks, zero
    eigh re-solves — and matches the eigh oracle to the grid tolerance."""
    import repro.kernels.newton_schulz as ns_mod
    b = 1536
    routed = []
    monkeypatch.setattr(
        ops, "ns_inverse_tiled",
        (lambda orig: lambda m, **kw: routed.append(m.shape) or orig(m, **kw)
         )(ops.ns_inverse_tiled))
    # the jnp reference iteration must never run on this path
    monkeypatch.setattr(
        kfac, "newton_schulz_inverse",
        lambda *a, **k: pytest.fail("tiled path fell back to the jnp "
                                    "reference iteration"))
    f = _spd_from_spectrum(_logspec(1e2), nb=1, b=b, seed=7)
    d = jnp.asarray(1e-1, jnp.float32)
    ns, info = dispatch.damped_inverse(f, d, method="newton_schulz",
                                       backend="pallas", ns_iters=20,
                                       return_info=True)
    assert routed == [(1, b, b)]
    # converged in-kernel: the eigh/SPD fallback must NOT have fired
    assert np.asarray(info["ns_converged"]).all(), info["ns_res"]
    eigh = dispatch.damped_inverse(f, d, method="eigh", backend="ref")
    scale = np.max(np.abs(np.asarray(eigh)))
    err = np.max(np.abs(np.asarray(ns) - np.asarray(eigh)))
    assert err <= 5e-3 * scale, err / scale
    # two-level structure sanity: the padded dim tiles exactly (1536 = 3*512)
    assert ops._ns_tile(b) == 512 and hasattr(ns_mod, "ns_tiled_residual")


def test_damped_inverse_unknown_method_raises():
    f = jnp.eye(4)[None]
    with pytest.raises(ValueError, match="unknown inverse method"):
        dispatch.damped_inverse(f, jnp.asarray(1e-3), method="qr",
                                backend="ref")


# ---------------------------------------------------------------------------
# dispatch unification: both Stage-4 call sites go through dispatch, and the
# pallas path never recomputes through the ref table entry
# ---------------------------------------------------------------------------

def _spy_lookup(monkeypatch):
    calls = []
    orig = dispatch.lookup

    def spy(op, backend):
        fn = orig(op, backend)
        calls.append((op, backend, fn))
        return fn

    monkeypatch.setattr(dispatch, "lookup", spy)
    return calls


def test_kfac_factor_inverses_route_through_dispatch(monkeypatch):
    calls = _spy_lookup(monkeypatch)
    a = _spd_from_spectrum(_logspec(1e2), seed=1)
    g = _spd_from_spectrum(_logspec(1e1), nb=1, b=8, seed=2)
    kfac.damped_factor_inverses(a, g, 1e-3, NB * B, 8,
                                method="newton_schulz", backend="pallas")
    hits = [(op, be) for op, be, _ in calls if op == "damped_inverse"]
    assert hits == [("damped_inverse", "pallas")] * 2     # A side + G side
    # the resolved callable is the kernel impl, not the ref table entry
    assert all(fn is dispatch._damped_inverse_pallas
               for op, _, fn in calls if op == "damped_inverse")


def test_ngd_stage4_no_ref_recompute_on_pallas_path(monkeypatch):
    """A full refresh step with backend="pallas" must resolve every
    damped_inverse through the pallas table entry — zero lookups of the ref
    implementation (the analogue of test_attention_grad's fused-VJP spy)."""
    from test_ngd_optimizer import (loss_fn, fstats_fn, counts_fn, INFOS,
                                    _data, D_IN, D_H)
    from repro.core.ngd import NGDConfig, SPNGD
    calls = _spy_lookup(monkeypatch)
    rng = np.random.RandomState(0)
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, 4) * 0.4, jnp.float32)}
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn,
                NGDConfig(damping=1e-3, backend="pallas",
                          inverse_method="newton_schulz"))
    state = opt.init(params)
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    jax.jit(opt.step)(params, state, _data(0), flags, 1e-3, 0.1, 0.9)
    hits = [(op, be) for op, be, _ in calls if op == "damped_inverse"]
    assert hits and all(be == "pallas" for _, be in hits)
    assert ("damped_inverse", "ref") not in [(op, be) for op, be in hits]


# ---------------------------------------------------------------------------
# e2e: 20-step ref-eigh vs pallas-Newton-Schulz train parity
# ---------------------------------------------------------------------------

def test_train_20_steps_ns_matches_eigh_jit():
    from test_backend_dispatch import _losses_jit
    l_eigh = _losses_jit("ref")                      # inverse_method="eigh"
    l_ns = _losses_jit("pallas", inverse_method="newton_schulz")
    assert np.isfinite(l_ns).all()
    assert l_ns[-1] < l_ns[0]
    # the NS preconditioner agrees with eigh to ~ns_tol, not bitwise, and
    # this overfit fixture is chaotic past ~step 8 (see
    # test_backend_dispatch): compare the pre-chaos prefix, then require
    # both runs to stay trained
    np.testing.assert_allclose(l_eigh[:8], l_ns[:8], rtol=1e-2, atol=1e-2)
    assert max(l_eigh[8:]) < 1.0 and max(l_ns[8:]) < 1.0


@pytest.mark.slow
def test_train_20_steps_ns_matches_eigh_shardmap():
    from repro.launch import compat
    from repro.launch.train import make_shardmap_train_step
    from test_backend_dispatch import _tiny_setup
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 virtual devices")
    losses = {}
    for name, backend, kw in (("eigh", "ref", {}),
                              ("ns", "pallas",
                               {"inverse_method": "newton_schulz"})):
        model, opt, params, state, batch, flags = _tiny_setup(backend, **kw)
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        with compat.set_mesh(mesh):
            step = jax.jit(make_shardmap_train_step(model, opt, mesh))
            out = []
            for _ in range(20):
                params, state, m = step(params, state, batch, flags,
                                        1e-3, 5e-3, 0.9)
                out.append(float(m["loss"]))
        losses[name] = out
    assert np.isfinite(losses["ns"]).all()
    np.testing.assert_allclose(losses["eigh"][:8], losses["ns"][:8],
                               rtol=1e-2, atol=1e-2)
    assert max(losses["eigh"][8:]) < 1.0 and max(losses["ns"][8:]) < 1.0


@pytest.mark.parametrize("factor_dtype", ["fp8_e4m3", "fp8_e5m2"])
def test_fp8_history_x_newton_schulz_smoke(factor_dtype):
    """fp8 factor history x NS inversion cross-product: the Stage-4
    recompute consumes PR 3's dequantized stale-side statistics through the
    Newton-Schulz path and still trains."""
    from test_backend_dispatch import _losses_jit
    l = _losses_jit("pallas", steps=8, inverse_method="newton_schulz",
                    factor_dtype=factor_dtype)
    assert np.isfinite(l).all()
    assert l[-1] < l[0]
