"""Stage-3 comm subsystem (repro.comm): the ISSUE-5 acceptance criteria.

  * CommConfig validation + per-strategy wire-dtype defaults;
  * scatter decisions single-sourced in FactorReducer (indivisible leading
    dims, single-device mesh, manual_axes "all" vs "auto");
  * replication fallback is counted, logged, and surfaced through
    IntervalController.summary();
  * reduce parity on a multi-device CPU mesh: dense bit-identical to a raw
    psum_scatter, ring within f32 reduction-reorder noise, ring_fp8 within
    the per-hop quantization bound;
  * ring_hop_pack/unpack dispatch ops bit-identical ref vs pallas;
  * wire-byte accounting: ring_fp8 <= 0.3x dense f32, ledger column moves;
  * 20-step e2e: --comm-strategy ring_fp8 loss-parity with dense f32 under
    shard_map (the pinned tolerance of the acceptance criterion).
"""
import os

import pytest

if "PYTEST_XDIST" not in os.environ and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import (CommConfig, FactorReducer, STRATEGIES,
                        make_comm_config, wire_stat_bytes)
from repro.core.stale import IntervalController
from repro.kernels import dispatch
from repro.launch import compat

needs_devices = pytest.mark.skipif(len(jax.devices()) < 8,
                                   reason="needs 8 virtual devices")


# ---------------------------------------------------------------------------
# config + accounting (host-side, no devices needed)
# ---------------------------------------------------------------------------

def test_comm_config_validation():
    assert CommConfig().strategy == "dense"
    with pytest.raises(ValueError, match="strategy"):
        CommConfig(strategy="tree")
    with pytest.raises(ValueError, match="wire"):
        CommConfig(wire_dtype="f16")
    with pytest.raises(ValueError, match="fp8"):
        CommConfig(strategy="ring_fp8")            # needs an fp8 wire dtype
    with pytest.raises(ValueError, match="f32"):
        CommConfig(strategy="dense", wire_dtype="fp8_e4m3")
    # the CLI constructor fills the per-strategy default
    assert make_comm_config("ring_fp8").wire_dtype == "fp8_e4m3"
    assert make_comm_config("ring").wire_dtype == "f32"
    assert make_comm_config("ring_fp8", "fp8_e5m2").wire_fmt == "e5m2"
    assert make_comm_config("dense").wire_fmt is None


def test_wire_stat_bytes_accounting():
    sym = (8, 2, 16, 16)                 # blocked symmetric factor
    t = 16 * 17 // 2
    dense = 8 * 2 * 16 * 16 * 4
    assert wire_stat_bytes(sym, True, make_comm_config("dense")) == dense
    assert wire_stat_bytes(sym, True, make_comm_config("ring")) \
        == 8 * 2 * t * 4
    assert wire_stat_bytes(sym, True, make_comm_config("ring_fp8")) \
        == 8 * 2 * (t + 4)
    # replication fallback always prices the raw f32 collective
    assert wire_stat_bytes(sym, True, make_comm_config("ring_fp8"),
                           scattered=False) == dense
    # non-symmetric stats ride the ring as dense f32 rows
    assert wire_stat_bytes((8, 5), False, make_comm_config("ring_fp8")) \
        == 8 * 5 * 4


def _mesh(shape=(4, 2)):
    return compat.make_mesh(shape, ("data", "model"))


def _template(shapes: dict):
    return {"fam": {k: jax.ShapeDtypeStruct(s, jnp.float32)
                    for k, s in shapes.items()}}


@needs_devices
def test_scatter_decisions_auto_vs_all():
    mesh = _mesh()                        # data=4, model=2
    auto = FactorReducer(mesh, manual_axes="auto")
    assert auto.dp == ("data",) and auto.ndev == 4
    assert auto.scatter_axes(8) == ("data",)
    assert auto.scatter_axes(2) == ()     # indivisible -> replicate
    assert auto.scatter_axes(6) == ()
    assert auto.out_spec((8, 3, 3)) == P(("data",), None, None)
    assert auto.out_spec((6, 3)) == P()

    full = FactorReducer(mesh, manual_axes="all")
    assert full.dp == ("data", "model") and full.ndev == 8
    assert full.scatter_axes(16) == ("data", "model")
    assert full.scatter_axes(4) == ("data",)   # falls back to data only
    assert full.scatter_axes(2) == ()


@needs_devices
def test_scatter_decisions_single_device_mesh():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    red = FactorReducer(mesh, manual_axes="auto",
                        template=_template({"a": (3, 2, 4, 4)}))
    # a 1-sized data axis divides everything: trivial scatter, no fallback
    assert red.ndev == 1
    assert red.scatter_axes(3) == ("data",)
    assert red.replicated == []


@needs_devices
def test_replication_tally_logged_and_in_summary(caplog):
    import logging
    mesh = _mesh()
    with caplog.at_level(logging.WARNING, logger="repro.comm.comm"):
        red = FactorReducer(mesh, template=_template(
            {"a": (8, 2, 4, 4), "g": (6, 2, 4, 4), "uw": (3, 4)}),
            sym_fn=lambda fam, key: key in ("a", "g"))
    assert sorted(red.replicated) == ["fam.g", "fam.uw"]
    assert any("fall back to fully replicated" in r.message
               for r in caplog.records)
    rep = red.scatter_report()
    assert rep["n_replicated"] == 2 and rep["n_stats"] == 3

    ctrl = IntervalController(["fam.a", "fam.g", "fam.uw"],
                              wire_bytes_per_stat=red.wire_bytes_per_stat())
    ctrl.record_comm(rep)
    s = ctrl.summary()["comm"]
    assert s["replicated_stats"] == ["fam.g", "fam.uw"]
    assert s["n_replicated"] == 2
    assert s["strategy"] == "dense"


def test_wire_ledger_column():
    ctrl = IntervalController(["x", "y"], alpha=0.5,
                              bytes_per_stat={"x": 10, "y": 20},
                              wire_bytes_per_stat={"x": 100, "y": 200})
    flags = {"x": True, "y": False}
    ctrl.update(1, flags, {"x": (0.0, 0.0)})
    s = ctrl.summary()["comm"]
    assert s["total_wire_bytes"] == 100       # only the refreshed stat
    assert s["dense_wire_bytes"] == 300       # refresh-every-step baseline
    # round-trips through the checkpoint codec
    ctrl2 = IntervalController.from_state_dict(ctrl.state_dict())
    assert ctrl2.total_wire_bytes == 100 and ctrl2.dense_wire_bytes == 300
    assert ctrl2.stats["y"].wire_bytes_per_refresh == 200


# ---------------------------------------------------------------------------
# ring hop codec dispatch ops (ref vs pallas bit parity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 36), (2, 3, 130)])
def test_ring_hop_pack_unpack_ref_vs_pallas(shape):
    rng = np.random.RandomState(0)
    rows = jnp.asarray(rng.randn(*shape) * 7, jnp.float32)
    pay_r, sc_r = jax.jit(
        lambda x: dispatch.ring_hop_pack(x, backend="ref"))(rows)
    pay_p, sc_p = dispatch.ring_hop_pack(rows, backend="pallas")
    assert pay_r.shape == shape and sc_r.shape == shape[:-1]
    np.testing.assert_array_equal(np.asarray(pay_r).view(np.uint8),
                                  np.asarray(pay_p).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(sc_r), np.asarray(sc_p))
    out_r = jax.jit(
        lambda p, s: dispatch.ring_hop_unpack(p, s, backend="ref"))(
            pay_r, sc_r)
    out_p = dispatch.ring_hop_unpack(pay_p, sc_p, backend="pallas")
    np.testing.assert_array_equal(np.asarray(out_r), np.asarray(out_p))
    # codec round-trip stays within the fp8 bound
    amax = np.abs(np.asarray(rows)).max(-1, keepdims=True)
    assert (np.abs(np.asarray(out_r) - np.asarray(rows))
            <= 0.25 * amax).all()


# ---------------------------------------------------------------------------
# reduce parity on the multi-device CPU mesh
# ---------------------------------------------------------------------------

def _reduce_with(mesh, manual_axes, strat, raw_all, template, sym_fn):
    red = FactorReducer(mesh, manual_axes=manual_axes,
                        comm=make_comm_config(strat), template=template,
                        sym_fn=sym_fn)

    def body(raw):
        return red.reduce(jax.tree.map(lambda x: x[0], raw))

    in_specs = jax.tree.map(lambda _: P(red.dp), raw_all)
    fn = compat.shard_map(body, mesh=mesh, in_specs=(in_specs,),
                          out_specs=red.out_specs(),
                          axis_names=set(red.dp))
    return jax.tree.map(np.asarray, jax.jit(fn)(raw_all)), red


@needs_devices
@pytest.mark.parametrize("manual_axes", ["auto", "all"])
def test_reduce_parity_dense_ring_ring_fp8(manual_axes):
    mesh = _mesh()
    ndev = 4 if manual_axes == "auto" else 8
    shapes = {"a": (8, 2, 16, 16),        # symmetric: rides the ring packed
              "d": (8, 6),                # non-symmetric: f32 ring
              "uw": (3, 4)}               # indivisible: replicated psum
    template = _template(shapes)
    sym_fn = lambda fam, key: key == "a"  # noqa: E731
    rng = np.random.RandomState(0)
    f = rng.randn(ndev, 8, 2, 16, 16).astype(np.float32)
    raw_all = {"fam": {"a": jnp.asarray(f + np.swapaxes(f, -1, -2)),
                       "d": jnp.asarray(rng.randn(ndev, 8, 6), np.float32),
                       "uw": jnp.asarray(rng.randn(ndev, 3, 4), np.float32)}}

    truth = jax.tree.map(lambda x: np.asarray(x).sum(0), raw_all)
    out = {}
    for strat in STRATEGIES:
        out[strat], red = _reduce_with(mesh, manual_axes, strat, raw_all,
                                       template, sym_fn)
        assert red.replicated == ["fam.uw"]
        # replicated fallback is strategy-independent plain psum
        np.testing.assert_allclose(out[strat]["fam"]["uw"],
                                   truth["fam"]["uw"], rtol=1e-6)

    # dense == the raw psum_scatter the pre-refactor train.py emitted,
    # bit for bit
    def psum_scatter_body(raw):
        v = raw["fam"]["a"][0]
        return jax.lax.psum_scatter(
            v, red.scatter_axes(v.shape[0]), scatter_dimension=0, tiled=True)

    raw_specs = jax.tree.map(lambda _: P(red.dp), raw_all)
    base = compat.shard_map(
        psum_scatter_body, mesh=mesh, in_specs=(raw_specs,),
        out_specs=red.out_spec(shapes["a"]), axis_names=set(red.dp))
    np.testing.assert_array_equal(out["dense"]["fam"]["a"],
                                  np.asarray(jax.jit(base)(raw_all)))

    # ring: same sums, different (hardware-ring) order -> f32 noise only
    for key in ("a", "d"):
        np.testing.assert_allclose(out["ring"]["fam"][key],
                                   out["dense"]["fam"][key],
                                   rtol=1e-5, atol=1e-5)
    # ring_fp8: symmetric stat quantizes per hop ((p-1) hops, one rounding
    # each, <= amax/28 per hop for e4m3 — pinned with margin); the
    # non-symmetric stat stays on the f32 ring
    amax = np.abs(out["dense"]["fam"]["a"]).max()
    err = np.abs(out["ring_fp8"]["fam"]["a"] - out["dense"]["fam"]["a"]).max()
    assert err <= 0.1 * amax, (err, amax)
    np.testing.assert_allclose(out["ring_fp8"]["fam"]["d"],
                               out["dense"]["fam"]["d"],
                               rtol=1e-5, atol=1e-5)

    # wire accounting: ring halves the symmetric payload, fp8 <= 0.3x dense
    wires = {s: sum(FactorReducer(
        mesh, manual_axes=manual_axes, comm=make_comm_config(s),
        template=template, sym_fn=sym_fn).wire_bytes_per_stat().values())
        for s in STRATEGIES}
    assert wires["ring"] < 0.65 * wires["dense"]
    assert wires["ring_fp8"] <= 0.3 * wires["dense"]


# ---------------------------------------------------------------------------
# e2e: the shard_map train step under each strategy
# ---------------------------------------------------------------------------

def _setup():
    from repro.configs import get_config
    from repro.core.ngd import NGDConfig, SPNGD
    from repro.models.transformer import DecoderLM
    cfg = get_config("llama3_2_1b").reduced(head_dim=32, d_ff=128,
                                            vocab=256, kfac_max_dim=64)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3))
    state = opt.init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (8, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (8, 16)),
                                   jnp.int32)}
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    return model, opt, params, state, batch, flags


@needs_devices
def test_e2e_ring_fp8_matches_dense_20_steps():
    """The acceptance criterion: --comm-strategy ring_fp8 reaches 20-step
    loss parity with dense f32 under shard_map. Mesh (2, 4) so the layer
    axis (L=2) scatters and every factor family actually rides the ring."""
    from repro.launch.train import make_shardmap_train_step
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    losses = {}
    for strat in ("dense", "ring_fp8"):
        model, opt, params, state, batch, flags = _setup()
        with compat.set_mesh(mesh):
            step = jax.jit(make_shardmap_train_step(
                model, opt, mesh, comm=make_comm_config(strat)))
            out = []
            for _ in range(20):
                params, state, m = step(params, state, batch, flags,
                                        1e-3, 5e-3, 0.9)
                out.append(float(m["loss"]))
        losses[strat] = out
        # every stat scatters on this mesh — the fp8 wire is exercised
        assert step.reducer.replicated == []
    assert np.isfinite(losses["ring_fp8"]).all()
    assert losses["ring_fp8"][-1] < losses["ring_fp8"][0]   # it trains
    # pre-chaos prefix tightly (see test_train_step_backends_match_20_steps
    # for why this overfit fixture diverges bitwise after ~8 steps), then
    # both runs must stay trained
    np.testing.assert_allclose(losses["dense"][:8], losses["ring_fp8"][:8],
                               rtol=2e-2, atol=2e-2)
    assert max(losses["dense"][8:]) < 1.0
    assert max(losses["ring_fp8"][8:]) < 1.0

    # measured wire bytes <= 0.3x the dense f32 collective (acceptance)
    wire = {s: sum(FactorReducer(
        mesh, comm=make_comm_config(s),
        template=jax.eval_shape(opt.fstats_fn),
        sym_fn=opt.sym_stat).wire_bytes_per_stat().values())
        for s in ("dense", "ring_fp8")}
    assert wire["ring_fp8"] <= 0.3 * wire["dense"], wire


@needs_devices
def test_shardmap_single_device_group_matches_jit():
    """Degenerate mesh (data axis of size 1): every strategy reduces to the
    local statistics — the shard_map step must match the plain jit step."""
    from repro.launch.train import make_train_step, make_shardmap_train_step
    model, opt, params, state, batch, flags = _setup()
    p_ref, s_ref, m_ref = jax.jit(make_train_step(model, opt))(
        params, state, batch, flags, 1e-3, 1e-2, 0.9)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        step = jax.jit(make_shardmap_train_step(
            model, opt, mesh, comm=make_comm_config("ring_fp8")))
        p_sm, s_sm, m_sm = step(params, state, batch, flags, 1e-3, 1e-2, 0.9)
    # p == 1: zero ring hops, so even ring_fp8 never quantizes
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_sm["loss"]),
                               rtol=1e-6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=2e-5, atol=2e-5), p_ref, p_sm)
