"""Unit tests for the launch-layer sharding policy (no compilation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import compat
from repro.launch import sharding as shd
from repro.launch.mesh import make_test_mesh
from repro.models.transformer import DecoderLM


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices")
    return make_test_mesh(2, 2)


def test_assign_prefers_batch_then_seq(mesh):
    def norm(spec):
        return tuple(x if not isinstance(x, tuple) or len(x) != 1 else x[0]
                     for x in tuple(spec))
    # batch divisible -> batch sharded
    assert norm(shd._assign((8, 64), mesh, [(("data",), [0, 1])])) == ("data", None)
    # batch=1 -> falls to the sequence dim (long_500k situation)
    assert norm(shd._assign((1, 64), mesh, [(("data",), [0, 1])])) == (None, "data")
    # nothing divisible -> replicated
    assert norm(shd._assign((1, 3), mesh, [(("data",), [0, 1])])) == (None, None)


def test_lead_axes_exact_vs_uneven(mesh):
    assert shd._lead_axes(8, mesh, exact=True) == ("data", "model")
    assert shd._lead_axes(3, mesh, exact=True) == ()      # 3 % 2 != 0
    assert shd._lead_axes(3, mesh, exact=False) == ("data",)  # padding ok
    assert shd._lead_axes(1, mesh, exact=False) == ()


def test_sanitize_drops_nondividing_axes(mesh):
    # vocab 32001 can't shard 2-way
    spec = shd._sanitize(P(None, "model"), (1600, 32001), mesh)
    assert spec == P(None, None)
    spec = shd._sanitize(P(None, "model"), (1600, 32000), mesh)
    assert spec == P(None, "model")


def test_param_pspecs_megatron_pairing(mesh):
    cfg = get_config("llama3_2_1b")
    model = DecoderLM(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = shd.params_pspecs(shapes, cfg, mesh=mesh)
    # column-parallel: outputs over model; row-parallel: inputs over model
    assert specs["blocks"]["attn"]["wq"][-1] == "model"
    assert specs["blocks"]["attn"]["wo"][-2] == "model"
    assert specs["blocks"]["mlp"]["up"][-1] == "model"
    assert specs["blocks"]["mlp"]["down"][-2] == "model"
    # norms replicated (sanitize pads with Nones; all entries must be None)
    assert all(x is None for x in tuple(specs["blocks"]["ln1"]["gamma"]))


def test_param_pspecs_fsdp_threshold(mesh):
    big = get_config("nemotron_4_340b")
    model = DecoderLM(big)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = shd.params_pspecs(shapes, big, mesh=mesh)
    # 2D: d_in additionally over data
    assert specs["blocks"]["attn"]["wq"][-2] == "data"
    small = get_config("llama3_2_1b")
    model_s = DecoderLM(small)
    shapes_s = jax.eval_shape(lambda: model_s.init(jax.random.PRNGKey(0)))
    specs_s = shd.params_pspecs(shapes_s, small, mesh=mesh)
    assert specs_s["blocks"]["attn"]["wq"][-2] is None


def test_cache_pspecs_gqa_and_long_context(mesh):
    from repro.configs import INPUT_SHAPES
    cfg = get_config("llama3_2_1b")
    model = DecoderLM(cfg)
    specs32 = shd.cache_pspecs(
        jax.eval_shape(lambda: model.init_cache(128, 32768)), mesh)
    def has(entry, name):
        return entry == name or entry == (name,)
    # batch over data, kv heads (8) over model (2-way ok)
    assert has(specs32["k"][1], "data")
    assert has(specs32["k"][3], "model")
    specs_long = shd.cache_pspecs(
        jax.eval_shape(lambda: model.init_cache(1, 524288)), mesh)
    # batch=1: data axes fall to the sequence dim
    assert has(specs_long["k"][2], "data")


def test_factor_sharding_hook_uneven_ok(mesh):
    hook = shd.factor_sharding_hook(mesh)
    x = jnp.zeros((5, 2, 8, 8))             # L=5 not divisible by 4
    with compat.set_mesh(mesh):
        out = jax.jit(lambda x: hook("blk/test", "a", x))(x)
    assert out.shape == x.shape
    y = jnp.zeros((3,))
    with compat.set_mesh(mesh):
        out = jax.jit(lambda y: hook("embed", "a", y))(y)  # non-blk: untouched
    assert out.shape == y.shape
