import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kfac

jax.config.update("jax_enable_x64", False)


def test_block_partition_exact():
    assert kfac.num_blocks(2048, 2048) == 1
    assert kfac.num_blocks(2049, 2048) == 2
    assert kfac.block_size(2049, 2048) == 1025
    assert kfac.padded_dim(2049, 2048) == 2050


def test_block_reshape_roundtrip():
    x = jnp.arange(24.0).reshape(2, 12)
    xb = kfac.block_reshape(x, 12, 5, axis=-1)   # nb=3, b=4
    assert xb.shape == (2, 3, 4)
    back = kfac.block_unreshape(xb, 12, axis=-2)
    np.testing.assert_allclose(back, x)


def test_factor_sum_matches_naive_blockdiag():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(50, 12), jnp.float32)
    f = kfac.factor_sum(x, max_dim=4)            # (3, 4, 4)
    full = np.asarray(x).T @ np.asarray(x)       # (12, 12)
    for k in range(3):
        np.testing.assert_allclose(f[k], full[4 * k:4 * k + 4, 4 * k:4 * k + 4],
                                   rtol=1e-5)


def test_factor_sum_padding():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(20, 10), jnp.float32)
    f = kfac.factor_sum(x, max_dim=4)            # nb=3, b=4, pad 2
    assert f.shape == (3, 4, 4)
    # padded rows/cols must be exactly zero
    np.testing.assert_allclose(f[2, 2:, :], 0.0)
    np.testing.assert_allclose(f[2, :, 2:], 0.0)


def test_damped_inverse_spd():
    rng = np.random.RandomState(2)
    m = rng.randn(6, 6)
    f = jnp.asarray(m @ m.T, jnp.float32)[None]  # (1, 6, 6)
    inv = kfac.damped_inverse(f, jnp.asarray([0.1]))
    expect = np.linalg.inv(np.asarray(f[0]) + 0.1 * np.eye(6))
    np.testing.assert_allclose(inv[0], expect, rtol=1e-4, atol=1e-5)


def test_cholesky_inverse_matches_eigh():
    rng = np.random.RandomState(3)
    m = rng.randn(8, 8)
    f = jnp.asarray(m @ m.T, jnp.float32)[None]
    i1 = kfac.damped_inverse(f, jnp.asarray([0.5]))
    i2 = kfac.cholesky_inverse(f, jnp.asarray([0.5]))
    np.testing.assert_allclose(i1, i2, rtol=1e-4, atol=1e-5)


def test_pi_correction_value():
    a = 2.0 * jnp.eye(4)[None]
    g = 8.0 * jnp.eye(2)[None]
    pi = kfac.pi_correction(a, g, 4, 2)
    np.testing.assert_allclose(pi, 0.5, rtol=1e-6)  # sqrt(2/8)


def test_damped_factor_inverses_eq12():
    # (A + pi sqrt(lam) I)^-1, (G + sqrt(lam)/pi I)^-1
    a = 2.0 * jnp.eye(4)[None]
    g = 8.0 * jnp.eye(2)[None]
    lam = 0.25
    a_inv, g_inv = kfac.damped_factor_inverses(a, g, lam, 4, 2)
    pi = 0.5
    np.testing.assert_allclose(a_inv[0], np.eye(4) / (2 + pi * 0.5), rtol=1e-5)
    np.testing.assert_allclose(g_inv[0], np.eye(2) / (8 + 0.5 / pi), rtol=1e-5)


def test_precondition_identity_is_noop():
    rng = np.random.RandomState(4)
    dw = jnp.asarray(rng.randn(10, 6), jnp.float32)
    a_inv = jnp.broadcast_to(jnp.eye(5), (2, 5, 5))   # blocked identity
    g_inv = jnp.broadcast_to(jnp.eye(3), (2, 3, 3))
    u = kfac.precondition(dw, a_inv, g_inv)
    np.testing.assert_allclose(u, dw, rtol=1e-5)


def test_precondition_matches_dense_kron():
    """Single-block preconditioning == dense Kronecker solve."""
    rng = np.random.RandomState(5)
    d_in, d_out = 5, 3
    ma = rng.randn(d_in, d_in)
    mg = rng.randn(d_out, d_out)
    a = jnp.asarray(ma @ ma.T + np.eye(d_in), jnp.float32)
    g = jnp.asarray(mg @ mg.T + np.eye(d_out), jnp.float32)
    dw = jnp.asarray(rng.randn(d_in, d_out), jnp.float32)
    a_inv = kfac.damped_inverse(a[None], jnp.asarray([0.0]))
    g_inv = kfac.damped_inverse(g[None], jnp.asarray([0.0]))
    u = kfac.precondition(dw, a_inv, g_inv)
    expect = np.linalg.inv(np.asarray(a)) @ np.asarray(dw) @ np.linalg.inv(np.asarray(g))
    np.testing.assert_allclose(u, expect, rtol=1e-3, atol=1e-4)


def test_precondition_diag_kinds():
    dw = jnp.ones((4, 3))
    a_inv = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    g_inv = jnp.asarray([1.0, 0.5, 0.25])
    u = kfac.precondition(dw, a_inv, g_inv)
    expect = np.outer([1, 2, 3, 4], [1, 0.5, 0.25])
    np.testing.assert_allclose(u, expect, rtol=1e-6)


def test_precondition_broadcasts_layer_axis():
    rng = np.random.RandomState(6)
    L, d_in, d_out = 3, 4, 4
    dw = jnp.asarray(rng.randn(L, d_in, d_out), jnp.float32)
    a_inv = jnp.broadcast_to(jnp.eye(4) * 2.0, (L, 1, 4, 4))
    g_inv = jnp.broadcast_to(jnp.eye(4) * 0.5, (L, 1, 4, 4))
    u = kfac.precondition(dw, a_inv, g_inv)
    np.testing.assert_allclose(u, dw, rtol=1e-5)


def test_unitwise_solve_2x2():
    # one channel: F = [[2, 1], [1, 3]], lam=0 -> solve F x = g
    stats = jnp.asarray([[2.0, 1.0, 3.0]])
    gg, gb = jnp.asarray([1.0]), jnp.asarray([0.0])
    ug, ub = kfac.unitwise_solve(stats, gg, gb, 0.0)
    f = np.array([[2, 1], [1, 3.0]])
    expect = np.linalg.solve(f, [1.0, 0.0])
    np.testing.assert_allclose([ug[0], ub[0]], expect, rtol=1e-5)


def test_sym_pack_roundtrip():
    rng = np.random.RandomState(7)
    m = rng.randn(6, 6)
    f = jnp.asarray(m + m.T, jnp.float32)
    p = kfac.sym_pack(f)
    assert p.shape == (21,)
    np.testing.assert_allclose(kfac.sym_unpack(p, 6), f, rtol=1e-6)


def test_sym_pack_batched():
    rng = np.random.RandomState(8)
    m = rng.randn(2, 3, 4, 4)
    f = jnp.asarray(m + np.swapaxes(m, -1, -2), jnp.float32)
    p = kfac.sym_pack(f)
    assert p.shape == (2, 3, 10)
    np.testing.assert_allclose(kfac.sym_unpack(p, 4), f, rtol=1e-6)


def test_frob_distance():
    x = jnp.ones((3, 3))
    y = jnp.zeros((3, 3))
    np.testing.assert_allclose(kfac.frob_distance(x, x), 0.0, atol=1e-7)
    d = kfac.frob_distance(2 * x, x)
    np.testing.assert_allclose(d, 1.0, rtol=1e-6)
