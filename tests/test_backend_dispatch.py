"""Backend dispatch parity: every hot-path op must agree between the ``ref``
(jnp einsum) and ``pallas`` (interpret mode on CPU) backends — including the
blocked (lead..., nb, b, b) factor layouts, odd/padded shapes (dims that are
not tile multiples), leading layer/expert axes, and bf16 inputs — and the
two backends must train end-to-end to matching losses."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kfac
from repro.kernels import dispatch, ops, ref


def _tol(dtype):
    return 1e-4 if dtype == jnp.float32 else 0.05


# ---------------------------------------------------------------------------
# factor_sum (statistics construction, §5.2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,max_dim", [
    ((64, 48), 48),          # single block
    ((100, 33), 16),         # d not a multiple of the block size (padded)
    ((3, 40, 30), 10),       # leading layer axis
    ((2, 3, 24, 20), 8),     # two leading axes (layer x expert)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_factor_sum_parity(shape, max_dim, dtype):
    rng = np.random.RandomState(hash((shape, max_dim)) % 2**31)
    x = jnp.asarray(rng.randn(*shape), dtype)
    a = kfac.factor_sum(x, max_dim, backend="ref")
    b = kfac.factor_sum(x, max_dim, backend="pallas")
    assert a.shape == b.shape and a.dtype == b.dtype == jnp.float32
    t = _tol(dtype)
    np.testing.assert_allclose(a, b, rtol=t, atol=t * 10)


# ---------------------------------------------------------------------------
# blocked preconditioning  U = A^-1 dW G^-1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lead,d_in,d_out,ba,bg", [
    ((), 32, 24, 32, 24),     # single block each side
    ((), 40, 30, 14, 12),     # padded blocks (dims not block multiples)
    ((3,), 40, 24, 14, 12),   # leading layer axis
    ((2, 2), 20, 16, 8, 8),   # layer x expert
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_precondition_parity(lead, d_in, d_out, ba, bg, dtype):
    rng = np.random.RandomState(hash((lead, d_in, d_out)) % 2**31)
    nba = kfac.num_blocks(d_in, ba)
    nbg = kfac.num_blocks(d_out, bg)
    ba_ = kfac.block_size(d_in, ba)
    bg_ = kfac.block_size(d_out, bg)
    dw = jnp.asarray(rng.randn(*lead, d_in, d_out), dtype)
    a_inv = jnp.asarray(rng.randn(*lead, nba, ba_, ba_), jnp.float32)
    g_inv = jnp.asarray(rng.randn(*lead, nbg, bg_, bg_), jnp.float32)
    u_ref = kfac.precondition(dw, a_inv, g_inv, backend="ref")
    u_pl = kfac.precondition(dw, a_inv, g_inv, backend="pallas")
    t = _tol(dtype)
    np.testing.assert_allclose(np.asarray(u_ref, np.float32),
                               np.asarray(u_pl, np.float32),
                               rtol=t, atol=t * 10)


def test_precondition_parity_one_sided_and_diag():
    rng = np.random.RandomState(0)
    dw = jnp.asarray(rng.randn(3, 40, 24), jnp.float32)
    a_inv = jnp.asarray(rng.randn(3, 3, 14, 14), jnp.float32)
    g_diag = jnp.asarray(rng.rand(3, 24) + 0.5, jnp.float32)
    for a, g in [(a_inv, None), (None, None), (a_inv, g_diag)]:
        u_ref = kfac.precondition(dw, a, g, backend="ref")
        u_pl = kfac.precondition(dw, a, g, backend="pallas")
        np.testing.assert_allclose(u_ref, u_pl, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# windowed attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,window", [(64, 16), (50, 13), (33, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_parity(s, window, dtype):
    rng = np.random.RandomState(s + window)
    bh, hd = 2, 16
    q = jnp.asarray(rng.randn(bh, s, hd), dtype)
    k = jnp.asarray(rng.randn(bh, s, hd), dtype)
    v = jnp.asarray(rng.randn(bh, s, hd), dtype)
    a = dispatch.swa_attention(q, k, v, window=window, backend="ref")
    b = dispatch.swa_attention(q, k, v, window=window, backend="pallas")
    t = 2e-4 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=t, atol=t)


def test_model_attention_pallas_route_matches_ref():
    """models.attention with backend="pallas" (kernel route incl. GQA repeat
    and custom-VJP wrapper) must match the chunked ref path, values AND
    gradients."""
    from repro.models.attention import attention
    rng = np.random.RandomState(3)
    b, s, h, kv, hd, w = 2, 24, 4, 2, 16, 12
    q = jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kv, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kv, hd), jnp.float32)
    o_ref = attention(q, k, v, window=w, backend="ref")
    o_pl = attention(q, k, v, window=w, backend="pallas")
    np.testing.assert_allclose(o_ref, o_pl, rtol=2e-4, atol=2e-4)

    f = lambda be: lambda q, k, v: jnp.sum(
        attention(q, k, v, window=w, backend=be) ** 2)
    g_ref = jax.grad(f("ref"), argnums=(0, 1, 2))(q, k, v)
    g_pl = jax.grad(f("pallas"), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_ref, g_pl):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# resolve() semantics + registry fallback
# ---------------------------------------------------------------------------

def test_resolve_auto_is_ref_on_cpu():
    assert jax.default_backend() != "tpu"  # test env invariant
    assert dispatch.resolve("auto", 4096) == "ref"
    assert dispatch.resolve(None, 4096) == "ref"
    assert dispatch.resolve("pallas", 8) == "pallas"
    with pytest.raises(ValueError):
        dispatch.resolve("mosaic", 8)


def test_resolve_auto_rejects_vacuous_dims():
    """all(()) is True — a dims-less "auto" would resolve to pallas on TPU
    unconditionally, so it must be an error. Explicit backends don't need
    dims (nothing to gate on)."""
    with pytest.raises(ValueError, match="at least one shape dim"):
        dispatch.resolve("auto")
    with pytest.raises(ValueError, match="at least one shape dim"):
        dispatch.resolve(None)
    assert dispatch.resolve("ref") == "ref"
    assert dispatch.resolve("pallas") == "pallas"


def test_lookup_unregistered_op_clear_error():
    with pytest.raises(KeyError, match="unregistered kernel op 'no_such'"):
        dispatch.lookup("no_such", "ref")


def test_kfac_factor_rejects_rectangular_tiles():
    """Survives python -O: a ValueError, not an assert."""
    x = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="square tiling"):
        ops.kfac_factor(x, bm=16, bn=32, interpret=True)


def test_direct_inverse_methods_degrade_pallas_to_ref():
    # eigh/cholesky are not matmul-shaped, so the pallas damped_inverse impl
    # must route them to the ref callable bit-for-bit (the same op-by-op
    # degradation an unregistered op gets); only method="newton_schulz"
    # engages the kernel
    rng = np.random.RandomState(1)
    m = rng.randn(2, 8, 8)
    f = jnp.asarray(m @ m.transpose(0, 2, 1) + 8 * np.eye(8), jnp.float32)
    for method in ("eigh", "cholesky"):
        a = dispatch.damped_inverse(f, jnp.asarray(1e-3), method=method,
                                    backend="ref")
        b = dispatch.damped_inverse(f, jnp.asarray(1e-3), method=method,
                                    backend="pallas")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unregistered_backend_falls_back_to_ref():
    # ops are ported one at a time: an op with no impl for the resolved
    # backend must fall back to ref instead of failing
    def only_ref(x):
        return x + 1.0
    dispatch.register("only_ref_op", "ref", only_ref)
    try:
        fn = dispatch.lookup("only_ref_op", "pallas")
        assert fn is only_ref
    finally:
        dispatch._TABLE.pop("only_ref_op", None)


# ---------------------------------------------------------------------------
# ops.kfac_block_precond grid/padding regression (bm != bk)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,bm,bk", [(40, 16, 10), (40, 10, 16), (33, 12, 9)])
def test_block_precond_mixed_tiles_pad_to_lcm(b, bm, bk):
    """When bm != bk the pad target must be a multiple of BOTH tile sizes;
    padding to max(bm, bk) leaves the last contraction tile hanging past the
    array."""
    rng = np.random.RandomState(b)
    binv = jnp.asarray(rng.randn(2, b, b), jnp.float32)
    w = jnp.asarray(rng.randn(2, b, 24), jnp.float32)
    out = ops.kfac_block_precond(binv, w, bm=bm, bn=16, bk=bk, interpret=True)
    np.testing.assert_allclose(out, ref.block_precond_ref(binv, w),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# end-to-end: NGDConfig(backend="pallas") trains and matches "ref"
# ---------------------------------------------------------------------------

def _tiny_setup(backend, arch="llama3_2_1b", **ngd_kw):
    """``ngd_kw`` forwards extra NGDConfig fields (inverse_method,
    factor_dtype, ...) so sibling suites can reuse this fixture for their
    own backend A/Bs (test_attention_grad, test_inverse_numerics)."""
    from repro.configs import get_config
    from repro.core.ngd import NGDConfig, SPNGD
    from repro.models.transformer import DecoderLM
    cfg = get_config(arch).reduced(
        head_dim=16, d_ff=64, vocab=128, sliding_window=8, kfac_max_dim=32)
    cfg = dataclasses.replace(cfg, backend=backend)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts,
                NGDConfig(damping=1e-3, backend=backend, **ngd_kw))
    state = opt.init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (4, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (4, 16)),
                                   jnp.int32)}
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    return model, opt, params, state, batch, flags


def _losses_jit(backend, steps=20, arch="llama3_2_1b", **ngd_kw):
    from repro.launch.train import make_train_step
    model, opt, params, state, batch, flags = _tiny_setup(backend, arch,
                                                          **ngd_kw)
    step = jax.jit(make_train_step(model, opt))
    out = []
    for _ in range(steps):
        params, state, m = step(params, state, batch, flags, 1e-3, 5e-3, 0.9)
        out.append(float(m["loss"]))
    return out


def test_train_step_backends_match_20_steps():
    l_ref = _losses_jit("ref")
    l_pl = _losses_jit("pallas")
    assert np.isfinite(l_pl).all()
    assert l_pl[-1] < l_pl[0]                    # it actually trains
    # The fused Pallas backward is numerically equivalent but not
    # bit-identical to ref (different reduction order), and this tiny
    # overfit fixture is chaotic once loss < 0.1: per-step f32 noise is
    # Lyapunov-amplified ~2x/step through the NGD preconditioner. Compare
    # the pre-chaos prefix tightly (a wrong gradient shows up at step 1),
    # then require both runs to stay trained.
    np.testing.assert_allclose(l_ref[:8], l_pl[:8], rtol=1e-3, atol=1e-3)
    assert max(l_ref[8:]) < 1.0 and max(l_pl[8:]) < 1.0


@pytest.mark.slow
def test_shardmap_train_step_backends_match():
    from repro.launch import compat
    from repro.launch.train import make_shardmap_train_step
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 virtual devices")
    losses = {}
    for backend in ("ref", "pallas"):
        model, opt, params, state, batch, flags = _tiny_setup(backend)
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        with compat.set_mesh(mesh):
            step = jax.jit(make_shardmap_train_step(model, opt, mesh))
            out = []
            for _ in range(20):
                params, state, m = step(params, state, batch, flags,
                                        1e-3, 5e-3, 0.9)
                out.append(float(m["loss"]))
        losses[backend] = out
    assert np.isfinite(losses["pallas"]).all()
    # prefix comparison: see test_train_step_backends_match_20_steps
    np.testing.assert_allclose(losses["ref"][:8], losses["pallas"][:8],
                               rtol=1e-3, atol=1e-3)
    assert max(losses["ref"][8:]) < 1.0 and max(losses["pallas"][8:]) < 1.0
