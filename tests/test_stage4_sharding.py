"""Stage-4 distribution + refresh pipelining (ISSUE-7 acceptance criteria).

  * gather byte accounting: sym-packed f32 triangles for sharded full-kind
    factors, 0 for replicated fallbacks / non-gatherable stats, surfaced
    through the IntervalController ledger (with state_dict back-compat);
  * on a simulated 8-device mesh each device inverts ONLY its
    FactorReducer-owned chunk, asserted via the ``return_info`` owner
    vector, and the gathered preconditioner matches the replicated inverse;
  * indivisible leading dims fall back to the replicated inverse (owner
    identically -1);
  * the double buffer: a refresh at step t stages inverses that activate
    at t+1 while t consumes the old buffer; no-refresh steps keep the whole
    curvature tree bit-exact;
  * 20-step e2e loss parity, sharded vs replicated Stage-4, under the
    shard_map schedule across dense / ring_fp8 / hier and vs the plain jit
    step (the Stage-3 wire strategy must not perturb inversion ownership).
"""
import dataclasses
import os

import pytest

if "PYTEST_XDIST" not in os.environ and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import (FactorReducer, Stage4Inverter, gather_stat_bytes,
                        make_comm_config, template_gather_bytes)
from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController, sym_packed_bytes
from repro.kernels import dispatch
from repro.launch import compat

needs_devices = pytest.mark.skipif(len(jax.devices()) < 8,
                                   reason="needs 8 virtual devices")


# ---------------------------------------------------------------------------
# gather byte accounting (host-side, no devices needed)
# ---------------------------------------------------------------------------

def test_gather_stat_bytes_accounting():
    sym = (8, 2, 16, 16)
    t = 16 * 17 // 2
    assert gather_stat_bytes(sym, True) == 8 * 2 * t * 4   # packed triangle
    assert gather_stat_bytes(sym, True, scattered=False) == 0  # no gather
    assert gather_stat_bytes((8, 5), False) == 8 * 5 * 4   # dense f32
    # the packed pricing is exactly the f32 sym_packed storage formula
    assert gather_stat_bytes(sym, True) == sym_packed_bytes(sym, 4)


def test_template_gather_bytes_full_factors_only():
    template = {"fam": {
        "a": jax.ShapeDtypeStruct((8, 2, 16, 16), jnp.float32),
        "g": jax.ShapeDtypeStruct((8, 1, 4, 4), jnp.float32),
        "d": jax.ShapeDtypeStruct((8, 16), jnp.float32),
        "uwf": jax.ShapeDtypeStruct((8, 4, 4), jnp.float32),
    }}
    sym = lambda fam, key: key in ("a", "g", "uwf")
    out = template_gather_bytes(template, sym)
    t = 16 * 17 // 2
    assert out["fam.a"] == 8 * 2 * t * 4
    assert out["fam.g"] == 8 * 1 * (4 * 5 // 2) * 4
    # diag stats are elementwise-inverted everywhere; uwf is inverted via
    # the direct (non-sharded) path — neither gathers
    assert out["fam.d"] == 0 and out["fam.uwf"] == 0
    # non-full ("diag") a/g factors never gather either
    nonfull = template_gather_bytes(template, lambda fam, key: False)
    assert set(nonfull.values()) == {0}


@needs_devices
def test_reducer_gather_bytes_respect_scatter_decisions():
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    template = {"fam": {
        "a": jax.ShapeDtypeStruct((8, 2, 16, 16), jnp.float32),   # scatters
        "g": jax.ShapeDtypeStruct((6, 2, 16, 16), jnp.float32),   # fallback
    }}
    red = FactorReducer(mesh, template=template,
                        sym_fn=lambda fam, key: True)
    out = red.gather_bytes_per_stat()
    assert out["fam.a"] == 8 * 2 * (16 * 17 // 2) * 4
    assert out["fam.g"] == 0            # replicated inverse: nothing gathers


def test_interval_controller_gather_ledger_and_compat():
    ctrl = IntervalController(["x", "y"], bytes_per_stat={"x": 10, "y": 20},
                              gather_bytes_per_stat={"x": 100, "y": 0})
    ctrl.update(1, {"x": True, "y": True}, {"x": (0.0, 0.0),
                                            "y": (0.0, 0.0)})
    ctrl.update(2, {"x": False, "y": False}, {})
    assert ctrl.total_gather_bytes == 100
    assert ctrl.dense_gather_bytes == 200
    s = ctrl.summary()["comm"]
    assert s["total_gather_bytes"] == 100
    assert s["dense_gather_bytes"] == 200
    # round trip
    ctrl2 = IntervalController.from_state_dict(ctrl.state_dict())
    assert ctrl2.state_dict() == ctrl.state_dict()
    # pre-PR-7 checkpoint: no gather ledger keys -> resume at zero
    old = ctrl.state_dict()
    del old["total_gather_bytes"], old["dense_gather_bytes"]
    for st in old["stats"].values():
        del st["gather_bytes_per_refresh"]
    ctrl3 = IntervalController.from_state_dict(old)
    assert ctrl3.total_gather_bytes == 0
    assert ctrl3.stats["x"].gather_bytes_per_refresh == 0


def test_spngd_gather_bytes_template():
    from test_ngd_optimizer import (loss_fn, fstats_fn, counts_fn, INFOS)
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn, NGDConfig())
    gb = opt.gather_bytes()
    assert set(gb) == set(opt.stat_names())
    # the tiny MLP's factors are full-kind: every a/g prices its triangle
    for name, b in gb.items():
        key = name.split(".")[-1]
        assert (b > 0) == (key in ("a", "g")), (name, b)


# ---------------------------------------------------------------------------
# shard-local inversion ownership (the 8-device acceptance criterion)
# ---------------------------------------------------------------------------

def _spd_blocks(lead, nb, b, seed=0):
    rng = np.random.RandomState(seed)
    m = rng.randn(lead, nb, b, 3 * b).astype(np.float32)
    f = np.einsum("lnbk,lnck->lnbc", m, m) / (3 * b)
    return jnp.asarray(f)


@needs_devices
def test_each_device_inverts_only_its_shard():
    """16 leading blocks over an 8-device group (manual_axes='all'): the
    gathered owner vector must show group index i produced exactly the
    contiguous chunk i — the psum_scatter(tiled=True) chunk assignment the
    Stage-3 reducer scattered with — and the gathered preconditioner must
    match the replicated inverse."""
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    lead, nb, b = 16, 2, 8
    template = {"fam": {"a": jax.ShapeDtypeStruct((lead, nb, b, b),
                                                  jnp.float32)}}
    red = FactorReducer(mesh, manual_axes="all", template=template,
                        sym_fn=lambda fam, key: True)
    assert red.ndev == 8
    inv4 = Stage4Inverter(red, method="eigh", backend="ref")
    f = _spd_blocks(lead, nb, b)
    damp = jnp.linspace(0.05, 0.2, lead).astype(jnp.float32)

    # host-side ownership map: contiguous chunks, one per group index
    np.testing.assert_array_equal(inv4.owners(lead),
                                  np.repeat(np.arange(8, dtype=np.int32), 2))

    with compat.set_mesh(mesh):
        inv, info = jax.jit(
            lambda f, d: inv4.invert(f, d, fam="fam", key="a",
                                     return_info=True))(f, damp)
    np.testing.assert_array_equal(np.asarray(info["owner"]),
                                  inv4.owners(lead))
    assert np.asarray(info["ns_converged"]).all()   # eigh: res == 0
    ref = dispatch.damped_inverse(f, damp[:, None], method="eigh",
                                  backend="ref")
    np.testing.assert_allclose(np.asarray(inv), np.asarray(ref),
                               rtol=2e-4, atol=1e-5)


@needs_devices
def test_indivisible_leading_dim_falls_back_to_replicated():
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    lead, nb, b = 6, 1, 8                    # 6 % 4 != 0: cannot scatter
    red = FactorReducer(mesh, template={"fam": {
        "a": jax.ShapeDtypeStruct((lead, nb, b, b), jnp.float32)}},
        sym_fn=lambda fam, key: True)
    inv4 = Stage4Inverter(red, method="eigh", backend="ref")
    f = _spd_blocks(lead, nb, b, seed=3)
    damp = jnp.full((lead,), 0.1, jnp.float32)
    np.testing.assert_array_equal(inv4.owners(lead),
                                  np.full((lead,), -1, np.int32))
    inv, info = inv4.invert(f, damp, fam="fam", key="a", return_info=True)
    np.testing.assert_array_equal(np.asarray(info["owner"]),
                                  np.full((lead,), -1, np.int32))
    ref = dispatch.damped_inverse(f, damp[:, None], method="eigh",
                                  backend="ref")
    np.testing.assert_array_equal(np.asarray(inv), np.asarray(ref))


# ---------------------------------------------------------------------------
# the double buffer (refresh at t activates at t+1)
# ---------------------------------------------------------------------------

def _tiny_opt(**kw):
    from test_ngd_optimizer import (loss_fn, fstats_fn, counts_fn, INFOS,
                                    _data, D_IN, D_H)
    rng = np.random.RandomState(7)
    params = {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.4, jnp.float32),
              "w2": jnp.asarray(rng.randn(D_H, 4) * 0.4, jnp.float32)}
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn, NGDConfig(**kw))
    return opt, params, opt.init(params), _data()


def _bitwise_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_double_buffer_activates_one_step_late():
    """Refresh at step 1 must stage the fresh inverses (precond_next) while
    the applied update still uses the init buffer; the fresh inverses become
    the active preconditioner at step 2."""
    opt_db, params, state_db, batch = _tiny_opt(double_buffer=True)
    opt_sb, _, state_sb, _ = _tiny_opt()
    flags = {k: jnp.asarray(True) for k in opt_db.stat_names()}
    args = (1e-3, 0.1, 0.0)

    p_db, s_db, _ = jax.jit(opt_db.step)(params, state_db, batch, flags,
                                         *args)
    p_sb, s_sb, _ = jax.jit(opt_sb.step)(params, state_sb, batch, flags,
                                         *args)
    # the staged buffer is EXACTLY the single-buffer fresh inverse...
    for fam in s_db["curv"]:
        assert _bitwise_equal(s_db["curv"][fam]["precond_next"],
                              s_sb["curv"][fam]["precond"])
        # ...while the active buffer is still the init (identity) one
        assert _bitwise_equal(s_db["curv"][fam]["precond"],
                              state_db["curv"][fam]["precond"])
    # the step-1 update therefore used the init buffer: identical to a
    # no-capture step from the init state (identity-preconditioned SGD)
    p_fast, _, _ = jax.jit(opt_db.step_fast)(params, state_db, batch, *args)
    np.testing.assert_allclose(np.asarray(p_db["w1"]),
                               np.asarray(p_fast["w1"]), rtol=2e-6,
                               atol=1e-7)

    # step 2 (fast): activation makes the staged inverses current, and the
    # applied update matches the single-buffer optimizer given the SAME
    # params/velocity (only the buffers differ between the two states)
    s_db2 = dict(s_db, velocity=s_sb["velocity"])
    p2_db, s2_db, _ = jax.jit(opt_db.step_fast)(p_sb, s_db2, batch, *args)
    p2_sb, _, _ = jax.jit(opt_sb.step_fast)(p_sb, s_sb, batch, *args)
    np.testing.assert_allclose(np.asarray(p2_db["w1"]),
                               np.asarray(p2_sb["w1"]), rtol=1e-6,
                               atol=1e-7)
    for fam in s2_db["curv"]:      # the swap persisted into the state
        assert _bitwise_equal(s2_db["curv"][fam]["precond"],
                              s2_db["curv"][fam]["precond_next"])


def test_double_buffer_no_refresh_is_bitexact():
    """With every flag off, a step must leave the whole double-buffered
    curvature tree bit-identical (the single-buffer invariant, extended)."""
    opt, params, state, batch = _tiny_opt(double_buffer=True)
    flags_on = {k: jnp.asarray(True) for k in opt.stat_names()}
    flags_off = {k: jnp.asarray(False) for k in opt.stat_names()}
    params, state, _ = jax.jit(opt.step)(params, state, batch, flags_on,
                                         1e-3, 0.1, 0.9)
    params, state, _ = jax.jit(opt.step_fast)(params, state, batch,
                                              1e-3, 0.1, 0.9)
    _, state2, _ = jax.jit(opt.step)(params, state, batch, flags_off,
                                     1e-3, 0.1, 0.9)
    assert _bitwise_equal(state2["curv"], state["curv"])


def test_upgrade_state_buffer_layouts():
    opt_sb, params, state_sb, _ = _tiny_opt()
    opt_db, _, state_db, _ = _tiny_opt(double_buffer=True)
    # single-buffer checkpoint -> double-buffer run: staged seeds active
    up = opt_db.upgrade_state(state_sb)
    for fam in up["curv"]:
        assert _bitwise_equal(up["curv"][fam]["precond_next"],
                              up["curv"][fam]["precond"])
    assert jax.tree.structure(up) == jax.tree.structure(state_db)
    # double-buffer checkpoint -> single-buffer run: staged copy dropped
    down = opt_sb.upgrade_state(state_db)
    assert jax.tree.structure(down) == jax.tree.structure(state_sb)
    # same-layout states pass through unchanged
    assert _bitwise_equal(opt_sb.upgrade_state(state_sb), state_sb)
    assert _bitwise_equal(opt_db.upgrade_state(state_db), state_db)


# ---------------------------------------------------------------------------
# e2e parity: sharded vs replicated Stage-4 (and vs plain jit)
# ---------------------------------------------------------------------------

def _llama_setup(ngd_kw):
    from repro.configs import get_config
    from repro.models.transformer import DecoderLM
    cfg = get_config("llama3_2_1b").reduced(head_dim=32, d_ff=128,
                                            vocab=256, kfac_max_dim=64)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3, **ngd_kw))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (8, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (8, 16)),
                                   jnp.int32)}
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    return model, opt, params, opt.init(params), batch, flags


def _losses_shardmap(strategy, steps=20, period=1, offset=0, lr=2e-3,
                     **ngd_kw):
    from repro.launch.train import (make_shardmap_fast_step,
                                    make_shardmap_train_step)
    # (2, 4): the layer axis (L=2) scatters, so Stage-4 actually shards
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    model, opt, params, state, batch, flags = _llama_setup(ngd_kw)
    with compat.set_mesh(mesh):
        comm = make_comm_config(strategy)
        step = jax.jit(make_shardmap_train_step(model, opt, mesh, comm=comm))
        # period > 1: capture on steps t % period == offset, fast steps in
        # between — the cadence train.py's loop drives (and the only legal
        # one for the chunked pipeline, whose drain rides the fast step)
        fast = (jax.jit(make_shardmap_fast_step(model, opt, mesh, comm=comm))
                if period > 1 else None)
        if ngd_kw.get("inverse_sharding"):
            assert opt.stage4 is not None       # the builder attached it
        out = []
        for t in range(steps):
            # lr gentler than the eager-refresh e2e tests: refreshing every
            # step against a one-step-stale buffer oscillates at 5e-3 on
            # this overfit fixture
            if t % period == offset:
                params, state, m = step(params, state, batch, flags,
                                        1e-3, lr, 0.9)
            else:
                params, state, m = fast(params, state, batch,
                                        1e-3, lr, 0.9)
            out.append(float(m["loss"]))
    return out


def _assert_loss_parity(a, b):
    # tight pre-chaos prefix (the shared e2e convention: this overfit
    # fixture diverges bitwise after ~8 steps), then both runs must END
    # trained — the one-step-stale buffer wobbles a few steps longer than
    # the eager refresh before settling, so the mid-run bound is on the tail
    np.testing.assert_allclose(a[:8], b[:8], rtol=2e-2, atol=2e-2)
    assert max(a[-4:]) < 1.0 and max(b[-4:]) < 1.0


@needs_devices
@pytest.mark.parametrize("strategy", [
    "dense",
    pytest.param("ring_fp8", marks=pytest.mark.slow),
    pytest.param("hier", marks=pytest.mark.slow)])
def test_e2e_sharded_matches_replicated_20_steps(strategy):
    """Sharded Stage-4 is a pure distribution of the inversion work: 20-step
    loss parity with the replicated refresh under every wire strategy."""
    repl = _losses_shardmap(strategy, double_buffer=True)
    shard = _losses_shardmap(strategy, double_buffer=True,
                             inverse_sharding=True)
    assert np.isfinite(shard).all() and shard[-1] < shard[0]
    _assert_loss_parity(repl, shard)


def _assert_pipeline_parity(base, pipe, k):
    """The pipeline-vs-inline e2e envelope. The two runs are PHASE-ALIGNED
    on activations (the inline baseline captures k steps after the pipeline,
    so fresh inverses go live on the same steps); until the first activation
    both apply identity-preconditioned SGD and must agree bitwise. From
    there the runs differ only in statistic age — the pipeline's activated
    stats are k steps staler, the algorithmic cost of hiding the refresh —
    measured at <=4% trajectory deviation on this fixture (vs the 2%
    same-age envelope), with both runs ending trained."""
    np.testing.assert_array_equal(base[:k + 2], pipe[:k + 2])
    np.testing.assert_allclose(pipe[:8], base[:8], rtol=5e-2, atol=5e-2)
    assert max(base[-4:]) < 0.2 and max(pipe[-4:]) < 0.2
    assert pipe[-1] < pipe[0] and np.isfinite(pipe).all()


@needs_devices
@pytest.mark.parametrize("strategy", [
    "dense",
    pytest.param("ring_fp8", marks=pytest.mark.slow)])
def test_e2e_chunked_pipeline_matches_double_buffer_20_steps(strategy):
    """ISSUE-10 acceptance: refresh_chunks=K at a capture-every-(K+1)-steps
    cadence tracks the inline double-buffer refresh whose activations land
    on the same steps. lr gentler still than the other e2e tests: the
    parity claim is about statistic age, so the fixture must not outrun the
    refresh cadence."""
    k = 2
    base = _losses_shardmap(strategy, period=k + 1, offset=k, lr=5e-4,
                            double_buffer=True)
    pipe = _losses_shardmap(strategy, period=k + 1, offset=0, lr=5e-4,
                            double_buffer=True, refresh_chunks=k)
    _assert_pipeline_parity(base, pipe, k)


@needs_devices
@pytest.mark.slow
def test_e2e_chunked_pipeline_with_sharded_stage4_20_steps():
    """The pipeline composes with inverse_sharding: each drain chunk's
    inversions run shard-local through Stage4Inverter (its own shard_map,
    opened from the fast step's GSPMD level) and gather per chunk."""
    k = 3
    base = _losses_shardmap("dense", period=k + 1, offset=k, lr=5e-4,
                            double_buffer=True, inverse_sharding=True)
    pipe = _losses_shardmap("dense", period=k + 1, offset=0, lr=5e-4,
                            double_buffer=True, inverse_sharding=True,
                            refresh_chunks=k)
    _assert_pipeline_parity(base, pipe, k)


@needs_devices
def test_e2e_sharded_matches_jit_20_steps():
    """...and with the plain jit schedule (replicated by construction —
    NGDConfig.inverse_sharding without a mesh is inert)."""
    from repro.launch.train import make_train_step
    model, opt, params, state, batch, flags = _llama_setup(
        {"double_buffer": True, "inverse_sharding": True})
    assert opt.stage4 is None                 # jit: nothing attaches it
    step = jax.jit(make_train_step(model, opt))
    ref = []
    for _ in range(20):
        params, state, m = step(params, state, batch, flags, 1e-3, 2e-3,
                                0.9)
        ref.append(float(m["loss"]))
    shard = _losses_shardmap("dense", double_buffer=True,
                             inverse_sharding=True)
    _assert_loss_parity(ref, shard)
