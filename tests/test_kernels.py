"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps + hypothesis property tests (deterministic fallback when
hypothesis isn't installed — see hypothesis_compat)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

I = dict(interpret=True)


# ---------------------------------------------------------------------------
# kfac_factor (SYRK)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(32, 16), (128, 64), (100, 48), (256, 128),
                                 (65, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_factor_shapes_dtypes(n, d, dtype):
    rng = np.random.RandomState(hash((n, d)) % 2**31)
    x = jnp.asarray(rng.randn(n, d), dtype)
    out = ops.kfac_factor(x, bm=32, bn=32, bk=64, **I)
    expect = ref.kfac_factor_ref(x)
    tol = 1e-4 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol * 10)


def test_factor_is_exactly_symmetric():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(64, 48), jnp.float32)
    out = np.asarray(ops.kfac_factor(x, bm=16, bn=16, bk=32, **I))
    np.testing.assert_array_equal(out, out.T)


@settings(deadline=None)
@given(n=st.integers(4, 96), d=st.integers(4, 64),
       bm=st.sampled_from([8, 16, 32]), bk=st.sampled_from([16, 32]))
def test_factor_property(n, d, bm, bk):
    rng = np.random.RandomState(n * 97 + d)
    x = jnp.asarray(rng.randn(n, d), jnp.float32)
    out = ops.kfac_factor(x, bm=bm, bn=bm, bk=bk, **I)
    np.testing.assert_allclose(out, ref.kfac_factor_ref(x), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# kfac_block_precond
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb,b,m", [(1, 32, 64), (3, 64, 48), (2, 40, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_precond(nb, b, m, dtype):
    rng = np.random.RandomState(hash((nb, b, m)) % 2**31)
    binv = jnp.asarray(rng.randn(nb, b, b), dtype)
    w = jnp.asarray(rng.randn(nb, b, m), dtype)
    out = ops.kfac_block_precond(binv, w, bm=16, bn=32, bk=16, **I)
    expect = ref.block_precond_ref(binv, w)
    tol = 1e-4 if dtype == jnp.float32 else 0.08
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol * 10)


@settings(deadline=None)
@given(nb=st.integers(1, 4), b=st.integers(8, 48), m=st.integers(8, 64))
def test_block_precond_property(nb, b, m):
    rng = np.random.RandomState(nb * 1000 + b * 10 + m)
    binv = jnp.asarray(rng.randn(nb, b, b), jnp.float32)
    w = jnp.asarray(rng.randn(nb, b, m), jnp.float32)
    out = ops.kfac_block_precond(binv, w, bm=16, bn=16, bk=16, **I)
    np.testing.assert_allclose(out, ref.block_precond_ref(binv, w),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,window", [(64, 0), (64, 16), (64, 7), (96, 32),
                                      (50, 13)])
def test_swa_attention(s, window):
    rng = np.random.RandomState(s + window)
    bh, hd = 4, 32
    q = jnp.asarray(rng.randn(bh, s, hd), jnp.float32)
    k = jnp.asarray(rng.randn(bh, s, hd), jnp.float32)
    v = jnp.asarray(rng.randn(bh, s, hd), jnp.float32)
    out = ops.swa_attention(q, k, v, window=window, bq=16, bk=16, **I)
    expect = ref.swa_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_swa_attention_bf16(dtype):
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(2, 32, 16), dtype)
    k = jnp.asarray(rng.randn(2, 32, 16), dtype)
    v = jnp.asarray(rng.randn(2, 32, 16), dtype)
    out = ops.swa_attention(q, k, v, window=8, bq=16, bk=16, **I)
    expect = ref.swa_attention_ref(q, k, v, window=8)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=0.05, atol=0.05)


def test_swa_matches_model_attention():
    """Kernel agrees with the model-layer chunked attention (same masking
    semantics) for MHA."""
    from repro.models.attention import attention
    rng = np.random.RandomState(9)
    b, s, h, hd, w = 2, 48, 2, 16, 12
    q = jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, hd), jnp.float32)
    model_out = attention(q, k, v, window=w, chunk=16)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kern = ops.swa_attention(qf, kf, vf, window=w, bq=16, bk=16, **I)
    kern = kern.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(kern, model_out, rtol=2e-4, atol=2e-4)


@settings(deadline=None)
@given(s=st.integers(8, 80), window=st.integers(0, 20),
       hd=st.sampled_from([8, 16, 32]))
def test_swa_property(s, window, hd):
    rng = np.random.RandomState(s * 31 + window)
    q = jnp.asarray(rng.randn(2, s, hd), jnp.float32)
    k = jnp.asarray(rng.randn(2, s, hd), jnp.float32)
    v = jnp.asarray(rng.randn(2, s, hd), jnp.float32)
    out = ops.swa_attention(q, k, v, window=window, bq=16, bk=16, **I)
    expect = ref.swa_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(out, expect, rtol=3e-4, atol=3e-4)
