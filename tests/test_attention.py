"""Chunked flash-style attention vs naive materialized-scores oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attention, attention_naive


def _qkv(seed, b, sq, sk, h, kv, hd, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, sq, h, hd), dtype)
    k = jnp.asarray(rng.randn(b, sk, kv, hd), dtype)
    v = jnp.asarray(rng.randn(b, sk, kv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 1)])
def test_chunked_matches_naive_causal(h, kv):
    q, k, v = _qkv(0, 2, 16, 16, h, kv, 8)
    out = attention(q, k, v, chunk=5)
    ref = attention_naive(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [1, 4, 7])
def test_sliding_window(window):
    q, k, v = _qkv(1, 2, 12, 12, 4, 2, 8)
    out = attention(q, k, v, window=window, chunk=4)
    ref = attention_naive(q, k, v, window=window)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_decode_with_cache_offset():
    """Sq=1 query at position 9 against a 16-slot cache with 10 valid."""
    q, k, v = _qkv(2, 2, 1, 16, 4, 4, 8)
    out = attention(q, k, v, q_offset=9, kv_len=jnp.asarray(10), chunk=4)
    ref = attention_naive(q[:, :, :, :], k[:, :10], v[:, :10], q_offset=9)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_decode_window_with_cache():
    q, k, v = _qkv(3, 1, 1, 32, 2, 2, 4)
    out = attention(q, k, v, q_offset=19, kv_len=jnp.asarray(20), window=8,
                    chunk=8)
    ref = attention_naive(q, k[:, :20], v[:, :20], q_offset=19, window=8)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_bfloat16_path():
    q, k, v = _qkv(4, 2, 8, 8, 4, 2, 8, jnp.bfloat16)
    out = attention(q, k, v, chunk=3)
    ref = attention_naive(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=0.05,
                               atol=0.05)


def test_grad_flows():
    q, k, v = _qkv(5, 1, 8, 8, 2, 2, 4)
    g = jax.grad(lambda q: attention(q, k, v, chunk=4).sum())(q)
    assert np.isfinite(np.asarray(g)).all()
