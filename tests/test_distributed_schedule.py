"""The 5-stage shard_map schedule (paper Algorithm 3) must be numerically
identical to the single-device / GSPMD-auto step, and its HLO must contain
the paper's collectives (reduce-scatter for factors — Stage 3).

Needs 8 virtual devices: run via conftest-selected env (see conftest.py).
"""
import os

import pytest

if "PYTEST_XDIST" not in os.environ and "XLA_FLAGS" not in os.environ:
    # only effective if jax is not yet initialized in this process
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.ngd import NGDConfig, SPNGD
from repro.launch import compat
from repro.launch.train import make_train_step, make_shardmap_train_step
from repro.models.transformer import DecoderLM

pytestmark = pytest.mark.skipif(len(jax.devices()) < 8,
                                reason="needs 8 virtual devices")


def _setup(arch="llama3_2_1b"):
    # extra-reduced shapes: this file compiles every step twice (ref + sm)
    cfg = get_config(arch).reduced(head_dim=32, d_ff=128, vocab=256,
                                   kfac_max_dim=64)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3))
    state = opt.init(params)
    rng = np.random.RandomState(0)
    b, s = 8, 16
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    return model, opt, params, state, batch, flags


def _mesh():
    return compat.make_mesh((4, 2), ("data", "model"))


@pytest.mark.parametrize("accum", [
    1, pytest.param(2, marks=pytest.mark.slow)])
def test_shardmap_matches_single_device(accum):
    model, opt, params, state, batch, flags = _setup()
    # reference: plain single-device step (microbatched the same way)
    ref_step = make_train_step(model, opt, accum=accum)
    p_ref, s_ref, m_ref = jax.jit(ref_step)(params, state, batch, flags,
                                            1e-3, 1e-2, 0.9)
    mesh = _mesh()
    with compat.set_mesh(mesh):
        sm_step = make_shardmap_train_step(model, opt, mesh, accum=accum)
        p_sm, s_sm, m_sm = jax.jit(sm_step)(params, state, batch, flags,
                                            1e-3, 1e-2, 0.9)
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_sm["loss"]),
                               rtol=1e-5)

    def close(a, b, tol):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        scale = np.abs(a).max() + 1e-6
        assert np.abs(a - b).max() < tol * scale, np.abs(a - b).max()

    # preconditioned updates involve near-singular inverses (eigh), so
    # compare with a scale-relative tolerance
    jax.tree.map(lambda a, b: close(a, b, 2e-3), p_ref, p_sm)
    jax.tree.map(lambda a, b: close(a, b, 5e-3),
                 s_ref["curv"], s_sm["curv"])


def test_shardmap_hlo_has_reduce_scatter():
    model, opt, params, state, batch, flags = _setup()
    mesh = _mesh()
    with compat.set_mesh(mesh):
        sm_step = make_shardmap_train_step(model, opt, mesh, accum=1)
        hlo = jax.jit(sm_step).lower(params, state, batch, flags,
                                     1e-3, 1e-2, 0.9).compile().as_text()
    assert "reduce-scatter" in hlo, "Stage-3 ReduceScatterV missing"


@pytest.mark.slow
def test_shardmap_loss_decreases():
    model, opt, params, state, batch, flags = _setup()
    mesh = _mesh()
    with compat.set_mesh(mesh):
        sm_step = jax.jit(make_shardmap_train_step(model, opt, mesh, accum=2))
        losses = []
        for _ in range(5):
            params, state, m = sm_step(params, state, batch, flags,
                                       1e-3, 2e-2, 0.9)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()