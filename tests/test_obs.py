"""Telemetry subsystem tests (repro.obs).

Pins the contracts the observability layer makes:
  * Span nesting/depth/parent bookkeeping and timing monotonicity.
  * JSONL schema: every event carries {v, type, t_wall}; loss floats
    round-trip bit-exactly through json.dumps/loads.
  * Disabled path is a true no-op: zero events, no file created, console
    output unchanged.
  * IntervalController.drain() is a lossless decomposition of the byte
    ledger: per-step deltas sum back to counters()/summary() exactly, and
    the drain snapshot survives a state_dict round-trip (with pre-drain
    checkpoint compat).
  * The instrumented tiny-MLP loop streams losses bit-identical to the
    returned step metrics and surfaces Stage-4 inversion info with the
    not-refreshed sentinel on keep-branch steps.
"""
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tagging
from repro.core.fisher import SiteInfo
from repro.core.ngd import NGDConfig, SPNGD
from repro.core.stale import IntervalController
from repro.core.tagging import FactorSpec
from repro.obs import MetricsLogger, Span, inverse_tally
from repro.obs import tracing

# ---------------------------------------------------------------------------
# tiny tagged MLP (mirrors tests/test_ngd_optimizer.py at toy scale)
# ---------------------------------------------------------------------------

D_IN, D_H, D_OUT, N = 6, 8, 4, 64
SPEC = FactorSpec(max_dim=64)


def loss_fn(params, fstats, batch):
    x, y = batch["x"], batch["y"]
    h = tagging.dense_site(x, params["w1"], fstats["l1"] if fstats else None, SPEC)
    h = jnp.tanh(h)
    o = tagging.dense_site(h, params["w2"], fstats["l2"] if fstats else None, SPEC)
    return jnp.mean((o - y) ** 2), {"logits": o}


def fstats_fn():
    return {"l1": tagging.make_stats(SPEC, D_IN, D_H),
            "l2": tagging.make_stats(SPEC, D_H, D_OUT)}


INFOS = {"l1": SiteInfo("dense", "w1", D_IN, D_H, SPEC),
         "l2": SiteInfo("dense", "w2", D_H, D_OUT, SPEC)}


def counts_fn(batch):
    n = batch["x"].shape[0]
    return {"l1": (n, n), "l2": (n, n)}


def _data(seed=0, n=N):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(n, D_IN), jnp.float32)
    w_true = rng.randn(D_IN, D_OUT)
    y = jnp.asarray(np.asarray(x) @ w_true + 0.01 * rng.randn(n, D_OUT),
                    jnp.float32)
    return {"x": x, "y": y}


def _params(seed=3):
    rng = np.random.RandomState(seed)
    return {"w1": jnp.asarray(rng.randn(D_IN, D_H) * 0.3, jnp.float32),
            "w2": jnp.asarray(rng.randn(D_H, D_OUT) * 0.3, jnp.float32)}


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_depth_parent_and_timing():
    recs = []
    with Span("outer", sink=recs.append):
        with Span("inner", sink=recs.append):
            pass
        with Span("inner2", sink=recs.append):
            pass
    # sinks fire at exit: inner, inner2, outer
    assert [r.name for r in recs] == ["inner", "inner2", "outer"]
    inner, inner2, outer = recs
    assert outer.depth == 0 and outer.parent is None
    assert inner.depth == 1 and inner.parent == "outer"
    assert inner2.depth == 1 and inner2.parent == "outer"
    # timing monotonicity: children start after the parent and fit inside it
    assert inner.start >= outer.start
    assert inner2.start >= inner.start + inner.dur
    assert inner.dur >= 0 and inner2.dur >= 0
    assert outer.dur >= (inner.dur + inner2.dur)
    assert inner.start + inner.dur <= outer.start + outer.dur


def test_span_stack_unwinds_on_exception():
    with pytest.raises(RuntimeError):
        with Span("boom"):
            raise RuntimeError("x")
    assert tracing._ACTIVE == []
    # stack is clean: a fresh span is top-level again
    with Span("after") as s:
        assert s.depth == 0 and s.parent is None


def test_stage_and_kernel_scopes_trace():
    # named_scope is trace-time metadata only — must compose with jit
    @jax.jit
    def f(x):
        with tracing.stage_scope(tracing.STAGE_INVERSE):
            with tracing.kernel_scope("damped_inverse", "ref"):
                return x * 2.0
    assert float(f(jnp.float32(3.0))) == 6.0


# ---------------------------------------------------------------------------
# metrics stream
# ---------------------------------------------------------------------------

def test_jsonl_schema_roundtrip(tmp_path):
    p = tmp_path / "m.jsonl"
    with MetricsLogger(str(p)) as log:
        assert log.enabled
        log.emit("run_config", arch="toy", n_params=7)
        log.log_step(1, loss=0.1234567890123, dt=0.01, lr=0.5, kind="refresh")
        log.log_step(2, loss=float(np.float32(1 / 3)), dt=0.02)
        log.console("hello world")
        assert log.events_written == 4
    lines = [json.loads(l) for l in p.read_text().splitlines()]
    assert len(lines) == 4
    for evt in lines:
        assert evt["v"] == 1
        assert isinstance(evt["type"], str)
        assert isinstance(evt["t_wall"], float)
    cfg, s1, s2, con = lines
    assert cfg["type"] == "run_config" and cfg["arch"] == "toy"
    assert s1["type"] == "step" and s1["lr"] == 0.5 and s1["kind"] == "refresh"
    # shortest-repr JSON floats round-trip bit-exactly
    assert s1["loss"] == 0.1234567890123
    assert s2["loss"] == float(np.float32(1 / 3))
    for k in ("dt", "dt_ema", "dt_p50", "dt_p99"):
        assert k in s1 and k in s2
    assert s1["dt_p50"] == 0.01 and s2["dt_p99"] == 0.02
    assert con["type"] == "console" and con["text"] == "hello world"


def test_disabled_logger_is_noop(tmp_path, capsys):
    log = MetricsLogger()
    assert not log.enabled
    log.emit("step", loss=1.0)
    log.log_step(1, loss=1.0, dt=0.1)
    with log.span("phase"):
        pass
    log.console("still prints")
    assert log.events_written == 0
    assert list(tmp_path.iterdir()) == []          # no file materialized
    assert capsys.readouterr().out == "still prints\n"
    log.close()


def test_console_text_byte_identical(tmp_path, capsys):
    p = tmp_path / "m.jsonl"
    text = "step    1 loss 7.2238 lr 0.0200 refresh 21/21"
    with MetricsLogger(str(p)) as log:
        log.console(text)
    assert capsys.readouterr().out == text + "\n"   # exactly what print() did
    evt = json.loads(p.read_text().splitlines()[0])
    assert evt["type"] == "console" and evt["text"] == text


def test_logger_path_stream_exclusive_and_stream_not_owned():
    with pytest.raises(ValueError):
        MetricsLogger("x.jsonl", stream=io.StringIO())
    buf = io.StringIO()
    log = MetricsLogger(stream=buf)
    log.emit("x")
    log.close()                                     # must NOT close caller's stream
    assert not buf.closed
    assert json.loads(buf.getvalue())["type"] == "x"


def test_span_events_reach_stream():
    buf = io.StringIO()
    log = MetricsLogger(stream=buf)
    with log.span("outer"):
        with log.span("inner"):
            pass
    evts = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert [e["name"] for e in evts] == ["inner", "outer"]
    assert evts[0]["depth"] == 1 and evts[0]["parent"] == "outer"
    assert evts[1]["depth"] == 0 and evts[1]["parent"] is None
    assert all(e["type"] == "span" and e["dur"] >= 0 for e in evts)


# ---------------------------------------------------------------------------
# inversion tallies
# ---------------------------------------------------------------------------

def test_inverse_tally_sentinel_and_rollup():
    info = {
        # one block not refreshed (sentinel -1), one clean, one fallback
        "l1.a": {"ns_res": np.array([-1.0, 0.0, 0.2]),
                 "ns_converged": np.array([True, True, False])},
        # same block size -> rolls up with l1.a
        "l1.g": {"ns_res": np.array([0.05]),
                 "ns_converged": np.array([True])},
        # nothing refreshed: excluded from the by-size rollup entirely
        "l2.a": {"ns_res": np.array([-1.0]),
                 "ns_converged": np.array([True])},
    }
    out = inverse_tally(info, {"l1.a": 8, "l1.g": 8, "l2.a": 4})
    s = out["stats"]
    assert s["l1.a"] == {"b": 8, "blocks": 3, "refreshed_blocks": 2,
                         "fallback_blocks": 1, "max_res": 0.2}
    assert s["l1.g"]["refreshed_blocks"] == 1 and s["l1.g"]["fallback_blocks"] == 0
    assert s["l2.a"]["refreshed_blocks"] == 0 and s["l2.a"]["max_res"] == 0.0
    assert out["by_block_size"] == {"8": {"refreshed_blocks": 3,
                                          "fallback_blocks": 1}}
    assert json.loads(json.dumps(out)) == out       # JSON-ready


# ---------------------------------------------------------------------------
# IntervalController drain ledger
# ---------------------------------------------------------------------------

def _run_ctrl(ctrl, steps, drain_each=None):
    rng = np.random.RandomState(0)
    for t in range(1, steps + 1):
        flags = ctrl.flags(t)
        # mixed similarities so intervals both grow and shrink
        sims = {k: ((0.5, 0.5) if rng.rand() < 0.3 else (0.0, 0.0))
                for k, v in flags.items() if v}
        ctrl.update(t, flags, sims)
        if drain_each is not None:
            drain_each.append(ctrl.drain())


def test_drain_sums_to_counters_exactly():
    ctrl = IntervalController(["a", "g"], alpha=0.1,
                              bytes_per_stat={"a": 100, "g": 50},
                              wire_bytes_per_stat={"a": 60, "g": 30},
                              gather_bytes_per_stat={"a": 10, "g": 5})
    drains = []
    _run_ctrl(ctrl, 25, drains)
    totals: dict = {}
    for d in drains:
        for k, v in d.items():
            totals[k] = totals.get(k, 0) + v
    cnt = ctrl.counters()
    assert totals == cnt                            # lossless decomposition
    s = ctrl.summary()
    assert cnt["total_stat_bytes"] == s["total_stat_bytes"]
    assert cnt["total_wire_bytes"] == s["comm"]["total_wire_bytes"]
    assert cnt["total_gather_bytes"] == s["comm"]["total_gather_bytes"]
    assert cnt["refresh_events"] == sum(st.refresh_count
                                        for st in ctrl.stats.values())
    # a drain with no intervening update is all-zero
    assert set(ctrl.drain().values()) == {0}


def test_drain_snapshot_survives_state_roundtrip():
    ctrl = IntervalController(["a", "g"], alpha=0.1,
                              bytes_per_stat={"a": 100, "g": 50})
    _run_ctrl(ctrl, 8)
    ctrl.drain()                                    # snapshot mid-run
    state = json.loads(json.dumps(ctrl.state_dict()))  # through JSON, as a ckpt
    restored = IntervalController.from_state_dict(state)
    # advance both identically: drains must agree (deltas, not totals)
    for c in (ctrl, restored):
        c.update(9, c.flags(9), {k: (0.0, 0.0) for k, v in c.flags(9).items() if v})
    assert ctrl.drain() == restored.drain()


def test_drain_pre_checkpoint_compat():
    """Checkpoints written before the drain ledger existed (no "drained"
    key) must load; the first drain then re-emits the full totals."""
    ctrl = IntervalController(["a"], alpha=0.1, bytes_per_stat={"a": 100})
    _run_ctrl(ctrl, 5)
    ctrl.drain()
    state = ctrl.state_dict()
    state.pop("drained")
    restored = IntervalController.from_state_dict(state)
    assert restored.drain() == restored.counters()


def test_summary_flat_is_scalar_only():
    ctrl = IntervalController(["a"], alpha=0.1, bytes_per_stat={"a": 100},
                              wire_bytes_per_stat={"a": 60})
    _run_ctrl(ctrl, 6)
    ctrl.record_comm({"strategy": "ring", "wire_dtype": "fp8",
                      "replicated": 2, "hops": 7.5, "ok": True})
    flat = ctrl.summary_flat()
    for k, v in flat.items():
        assert isinstance(v, (int, float)) and not isinstance(v, bool), k
    assert flat["steps"] == 6
    assert flat["comm_replicated"] == 2 and flat["comm_hops"] == 7.5
    assert "comm_strategy" not in flat and "comm_ok" not in flat
    assert flat["reduction_rate"] == ctrl.reduction_rate()
    s = ctrl.summary()
    assert flat["wire_reduction_rate"] == s["comm"]["wire_reduction_rate"]
    assert json.loads(json.dumps(flat)) == flat


# ---------------------------------------------------------------------------
# instrumented end-to-end loop
# ---------------------------------------------------------------------------

def test_e2e_stream_losses_bit_identical(tmp_path):
    """10 instrumented steps: the JSONL stream's losses are bit-identical
    to the returned step metrics, drains sum to the ledger, and the
    Stage-4 inversion info carries the -1 sentinel exactly on keep-branch
    (no-refresh) families."""
    batch = _data()
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn,
                NGDConfig(damping=1e-3, inverse_info=True))
    params = _params()
    state = opt.init(params)
    step_j = jax.jit(opt.step)
    stat_names = [f"{f}.{k}" for f in ("l1", "l2") for k in ("a", "g")]
    # huge alpha: everything always reads "similar", so Algorithm 2 grows
    # the intervals Fibonacci-style and the loop mixes refresh + fast steps
    ctrl = IntervalController(stat_names, alpha=1e9,
                              bytes_per_stat={n: 64 for n in stat_names})
    p = tmp_path / "m.jsonl"
    losses, refresh_kinds = [], []
    with MetricsLogger(str(p)) as log:
        for t in range(1, 11):
            flags = ctrl.flags(t)
            jflags = {k: jnp.asarray(v) for k, v in flags.items()}
            params, state, m = step_j(params, state, batch, jflags,
                                      1e-3, 0.1, 0.9)
            refreshed = any(flags.values())
            ctrl.update(t, flags, {k: (float(v[0]), float(v[1]))
                                   for k, v in m["sims"].items()} if refreshed
                        else {})
            loss = float(m["loss"])
            losses.append(loss)
            refresh_kinds.append("refresh" if refreshed else "fast")
            # sentinel contract: refreshed families carry real residuals,
            # kept families carry exactly -1 everywhere
            inv = m["inverse_info"]
            assert set(inv) == set(stat_names)
            for name, info in inv.items():
                fam = name.split(".")[0]
                fam_refreshed = any(flags[f"{fam}.{k}"] for k in ("a", "g"))
                res = np.asarray(info["ns_res"])
                if fam_refreshed:
                    assert (res >= 0.0).all()
                else:
                    assert (res == -1.0).all()
            log.log_step(t, loss=loss, dt=0.01,
                         kind=refresh_kinds[-1],
                         grad_norm=float(m["grad_norm"]),
                         update_norm=float(m["update_norm"]),
                         comm=ctrl.drain(),
                         inverse=inverse_tally(inv, {}))
        log.emit("summary", **ctrl.summary_flat())
    evts = [json.loads(l) for l in p.read_text().splitlines()]
    steps = [e for e in evts if e["type"] == "step"]
    assert len(steps) == 10
    assert [e["loss"] for e in steps] == losses     # bit-identical round-trip
    assert [e["kind"] for e in steps] == refresh_kinds
    assert "fast" in refresh_kinds and "refresh" in refresh_kinds
    # per-step comm drains sum back to the final summary totals exactly
    summary = [e for e in evts if e["type"] == "summary"][0]
    totals: dict = {}
    for e in steps:
        for k, v in e["comm"].items():
            totals[k] = totals.get(k, 0) + v
    for k, v in totals.items():
        assert summary[k] == v, k
    assert summary["steps"] == 10
    # the tally on the final step: direct eigh inverses never fall back
    last = steps[-1]["inverse"]["stats"]
    assert all(s["fallback_blocks"] == 0 for s in last.values())


def test_inverse_info_off_by_default():
    """cfg.inverse_info defaults False: the step metric tree is unchanged
    from the seed (no inverse_info key), so existing consumers see the
    exact pytree they always did."""
    batch = _data()
    opt = SPNGD(loss_fn, INFOS, fstats_fn, counts_fn, NGDConfig(damping=1e-3))
    params = _params()
    state = opt.init(params)
    flags = {k: jnp.asarray(True)
             for k in ("l1.a", "l1.g", "l2.a", "l2.g")}
    _, _, m = jax.jit(opt.step)(params, state, batch, flags, 1e-3, 0.1, 0.0)
    assert "inverse_info" not in m
    assert {"loss", "sims", "grad_norm", "update_norm"} <= set(m)
