"""PR-6 Stage-3 additions: hierarchical two-level reduce + fused wire path.

  * hier_split topology math + CommConfig devices_per_host validation;
  * hier reduce parity vs dense on a simulated 2-host x 4-device mesh
    (both levels active: intra-host f32 psum_scatter, inter-host fp8 ring);
  * per-level wire-byte ledger: wire_stat_level_bytes hand-check, reducer
    breakdown, IntervalController intra/inter columns + checkpoint codec,
    and the acceptance bound inter-host <= 0.2x dense f32;
  * fused capture: factor_sum_wire ref-vs-pallas bit parity on the scales,
    the lookup spy proving the SYRK call site emits wire-format payloads
    with ZERO separate ring_hop_pack dispatches, and 20-step e2e loss
    parity with dense under both jit and shard_map;
  * the accum>1 + wire-template guard.
"""
import os

import pytest

if "PYTEST_XDIST" not in os.environ and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import (CommConfig, FactorReducer, hier_split,
                        make_comm_config, wire_stat_bytes,
                        wire_stat_level_bytes)
from repro.core.stale import IntervalController, sym_packed_bytes
from repro.kernels import dispatch
from repro.launch import compat
from repro.quant import encoded_nbytes

needs_devices = pytest.mark.skipif(len(jax.devices()) < 8,
                                   reason="needs 8 virtual devices")


# ---------------------------------------------------------------------------
# topology + accounting (host-side, no devices needed)
# ---------------------------------------------------------------------------

def test_hier_config_and_split():
    # hier defaults to the fp8 wire like ring_fp8
    assert make_comm_config("hier").wire_dtype == "fp8_e4m3"
    assert make_comm_config("fused").wire_dtype == "fp8_e4m3"
    with pytest.raises(ValueError, match="devices_per_host"):
        CommConfig(strategy="hier", wire_dtype="fp8_e4m3", devices_per_host=0)
    cfg4 = make_comm_config("hier", devices_per_host=4)
    assert cfg4.local_devices() == 4
    # D = gcd(devices_per_host, p), H = p / D
    assert hier_split(cfg4, 8) == (4, 2)     # 2 hosts x 4 devices
    assert hier_split(cfg4, 4) == (4, 1)     # one host: pure psum_scatter
    assert hier_split(make_comm_config("hier", devices_per_host=1), 8) \
        == (1, 8)                            # degenerate: pure ring
    assert hier_split(cfg4, 6) == (2, 3)     # non-divisible: gcd grouping
    assert hier_split(cfg4, 1) == (1, 1)


def test_wire_level_bytes_accounting():
    shape = (8, 2, 16, 16)                   # blocked symmetric factor
    dense = 8 * 2 * 16 * 16 * 4
    packed = sym_packed_bytes(shape)         # f32 triangles
    fp8 = encoded_nbytes(shape, symmetric=True)
    cfg = make_comm_config("hier", devices_per_host=4)

    # 2 hosts x 4 devices: full packed f32 intra, fp8/D slice inter
    intra, inter = wire_stat_level_bytes(shape, True, cfg, group_size=8)
    assert (intra, inter) == (packed, fp8 // 4)
    assert wire_stat_bytes(shape, True, cfg, group_size=8) == intra + inter
    # acceptance bound: inter-host level <= 0.2x the dense f32 collective
    assert inter <= 0.2 * dense

    # one host: no inter level; one device per host: no intra level
    assert wire_stat_level_bytes(shape, True, cfg, group_size=4) \
        == (packed, 0)
    cfg1 = make_comm_config("hier", devices_per_host=1)
    assert wire_stat_level_bytes(shape, True, cfg1, group_size=8) \
        == (0, fp8)
    # non-symmetric stats ride both levels as dense f32
    assert wire_stat_level_bytes((8, 6), False, cfg, group_size=8) \
        == (8 * 6 * 4, 8 * 6 * 4 // 4)
    # replication fallback bills its dense psum to the inter column
    assert wire_stat_level_bytes(shape, True, cfg, scattered=False) \
        == (0, dense)
    # flat strategies have no level split at all
    assert wire_stat_level_bytes(shape, True, make_comm_config("ring_fp8"),
                                 group_size=8) == (0, 0)


def test_interval_controller_level_ledger():
    ctrl = IntervalController(
        ["x", "y"], alpha=0.5,
        wire_bytes_per_stat={"x": 130, "y": 260},
        wire_level_bytes_per_stat={"x": (100, 30), "y": (200, 60)})
    ctrl.update(1, {"x": True, "y": False}, {"x": (0.0, 0.0)})
    s = ctrl.summary()["comm"]
    assert s["total_wire_intra_bytes"] == 100    # only the refreshed stat
    assert s["total_wire_inter_bytes"] == 30
    assert s["dense_wire_intra_bytes"] == 300    # refresh-every-step
    assert s["dense_wire_inter_bytes"] == 90
    # round-trips through the checkpoint codec
    ctrl2 = IntervalController.from_state_dict(ctrl.state_dict())
    assert ctrl2.total_wire_inter_bytes == 30
    assert ctrl2.stats["y"].wire_intra_bytes_per_refresh == 200
    # pre-PR-6 checkpoints (no level columns) restore at zero
    old = ctrl.state_dict()
    for k in ("total_wire_intra_bytes", "dense_wire_intra_bytes",
              "total_wire_inter_bytes", "dense_wire_inter_bytes"):
        old.pop(k)
    for st in old["stats"].values():
        st.pop("wire_intra_bytes_per_refresh")
        st.pop("wire_inter_bytes_per_refresh")
    ctrl3 = IntervalController.from_state_dict(old)
    assert ctrl3.total_wire_inter_bytes == 0


# ---------------------------------------------------------------------------
# fused capture kernel: ref vs pallas(interpret) parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scale_mode", ["fp32", "pow2"])
def test_factor_sum_wire_ref_vs_pallas(scale_mode):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(3, 64, 32) * 2, jnp.float32)  # (lead, n, d)
    pay_r, sc_r = dispatch.factor_sum_wire(x, 16, scale_mode=scale_mode,
                                           backend="ref")
    pay_p, sc_p = dispatch.factor_sum_wire(x, 16, scale_mode=scale_mode,
                                           backend="pallas")
    t = 16 * 17 // 2
    assert pay_r.shape == (3, 2, t) and sc_r.shape == (3, 2)
    # identical scale math (explicit reciprocal-multiply in both paths)
    np.testing.assert_array_equal(np.asarray(sc_r), np.asarray(sc_p))
    np.testing.assert_array_equal(np.asarray(pay_r).view(np.uint8),
                                  np.asarray(pay_p).view(np.uint8))
    # decode matches the dense factor sum within the e4m3 bound
    from repro import quant
    dense = dispatch.factor_sum(x, 16, backend="ref")
    dec = quant.decode_wire_stat({"payload": pay_r, "scale": sc_r})
    amax = np.abs(np.asarray(dense)).max()
    assert np.abs(np.asarray(dec) - np.asarray(dense)).max() <= 0.05 * amax


# ---------------------------------------------------------------------------
# hier reduce parity on the simulated 2-host x 4-device mesh
# ---------------------------------------------------------------------------

def _template(shapes: dict):
    return {"fam": {k: jax.ShapeDtypeStruct(s, jnp.float32)
                    for k, s in shapes.items()}}


def _reduce_with(mesh, manual_axes, comm, raw_all, template, sym_fn):
    red = FactorReducer(mesh, manual_axes=manual_axes, comm=comm,
                        template=template, sym_fn=sym_fn)

    def body(raw):
        return red.reduce(jax.tree.map(lambda x: x[0], raw))

    in_specs = jax.tree.map(lambda _: P(red.dp), raw_all)
    fn = compat.shard_map(body, mesh=mesh, in_specs=(in_specs,),
                          out_specs=red.out_specs(),
                          axis_names=set(red.dp))
    return jax.tree.map(np.asarray, jax.jit(fn)(raw_all)), red


@needs_devices
@pytest.mark.parametrize("devices_per_host", [4, 1, 8])
def test_hier_reduce_parity_two_level(devices_per_host):
    """hier vs dense on an 8-device group modelled as 2 hosts x 4 devices
    (plus the degenerate pure-ring and pure-psum_scatter splits)."""
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    shapes = {"a": (8, 2, 16, 16),        # symmetric: fp8 inter-host ring
              "d": (8, 6)}                # non-symmetric: f32 both levels
    template = _template(shapes)
    sym_fn = lambda fam, key: key == "a"  # noqa: E731
    rng = np.random.RandomState(0)
    f = rng.randn(8, 8, 2, 16, 16).astype(np.float32)
    raw_all = {"fam": {"a": jnp.asarray(f + np.swapaxes(f, -1, -2)),
                       "d": jnp.asarray(rng.randn(8, 8, 6), np.float32)}}

    dense_out, _ = _reduce_with(mesh, "all", make_comm_config("dense"),
                                raw_all, template, sym_fn)
    hier_out, red = _reduce_with(
        mesh, "all",
        make_comm_config("hier", devices_per_host=devices_per_host),
        raw_all, template, sym_fn)
    d, h = hier_split(red.comm, 8)
    assert (d, h) == {4: (4, 2), 1: (1, 8), 8: (8, 1)}[devices_per_host]
    assert red.scatter_report()["hier_topology"] == {
        "devices_per_host": d, "hosts": h}

    # ownership is strategy-invariant (same out_specs as dense), so outputs
    # compare elementwise; symmetric stat quantizes only on inter-host hops
    amax = np.abs(dense_out["fam"]["a"]).max()
    err = np.abs(hier_out["fam"]["a"] - dense_out["fam"]["a"]).max()
    if h == 1:
        assert err <= 1e-5 * amax, (err, amax)   # pure f32 psum_scatter
    else:
        assert err <= 0.1 * amax, (err, amax)    # (h-1) fp8 roundings
    # non-symmetric stat never quantizes
    np.testing.assert_allclose(hier_out["fam"]["d"], dense_out["fam"]["d"],
                               rtol=1e-5, atol=1e-5)


@needs_devices
def test_hier_level_ledger_on_mesh():
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    shapes = {"a": (8, 2, 16, 16), "uw": (3, 4)}   # uw: replicated fallback
    red = FactorReducer(mesh, manual_axes="all",
                        comm=make_comm_config("hier", devices_per_host=4),
                        template=_template(shapes),
                        sym_fn=lambda fam, key: key == "a")
    levels = red.wire_bytes_per_stat_levels()
    packed = sym_packed_bytes(shapes["a"])
    fp8 = encoded_nbytes(shapes["a"], symmetric=True)
    dense_a = int(np.prod(shapes["a"])) * 4
    assert levels["fam.a"] == (packed, fp8 // 4)
    assert levels["fam.a"][1] <= 0.2 * dense_a       # acceptance bound
    # replication fallback bills dense f32 to the inter column
    assert levels["fam.uw"] == (0, int(np.prod(shapes["uw"])) * 4)
    # flat sum stays consistent with the scalar ledger
    per_stat = red.wire_bytes_per_stat()
    assert per_stat["fam.a"] == sum(levels["fam.a"])

    ctrl = IntervalController(list(per_stat), wire_bytes_per_stat=per_stat,
                              wire_level_bytes_per_stat=levels)
    ctrl.record_comm(red.scatter_report())
    flags = {n: True for n in per_stat}
    ctrl.update(1, flags, {n: (0.0, 0.0) for n in per_stat})
    s = ctrl.summary()["comm"]
    assert s["total_wire_intra_bytes"] == packed
    assert s["total_wire_inter_bytes"] == fp8 // 4 + 3 * 4 * 4
    assert s["hier_topology"] == {"devices_per_host": 4, "hosts": 2}


# ---------------------------------------------------------------------------
# fused capture: lookup spy + e2e parity
# ---------------------------------------------------------------------------

def _setup(factor_wire: str = "", n_layers: int = 0):
    from repro.configs import get_config
    from repro.core.ngd import NGDConfig, SPNGD
    from repro.models.transformer import DecoderLM
    cfg = get_config("llama3_2_1b").reduced(head_dim=32, d_ff=128,
                                            vocab=256, kfac_max_dim=64)
    if n_layers:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    if factor_wire:
        cfg = dataclasses.replace(cfg, factor_wire=factor_wire)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts, NGDConfig(damping=1e-3))
    state = opt.init(params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (8, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (8, 16)),
                                   jnp.int32)}
    flags = {k: jnp.asarray(True) for k in opt.stat_names()}
    return model, opt, params, state, batch, flags


def test_wire_template_and_state_shapes():
    """Wire capture changes the raw-stat template to payload/scale dicts but
    leaves the optimizer state (history, preconditioner) dense."""
    from repro import quant
    model, opt, params, state, *_ = _setup(factor_wire="e4m3")
    template = jax.eval_shape(opt.fstats_fn)
    wired = [(fam, k) for fam, stats in template.items()
             for k, leaf in stats.items() if quant.is_wire(leaf)]
    assert wired, "no wire-format stats captured"
    for fam, k in wired:
        entry = template[fam][k]
        assert entry["payload"].dtype == jnp.float8_e4m3fn
        assert entry["scale"].dtype == jnp.float32
        dense = quant.wire_dense_shape(entry)
        assert state["curv"][fam]["prev"][k].shape == dense
    # ledger prices the decoded dense shape, not the packed payload
    model_d, opt_d, *_ = _setup()
    assert opt.stat_bytes() == opt_d.stat_bytes()


@needs_devices
def test_fused_spy_syrk_emits_wire_no_ring_hop_pack(monkeypatch):
    """Acceptance: under the fused strategy the SYRK call site emits
    wire-format payloads (factor_sum_wire dispatches) and the reducer
    consumes them pre-packed — ZERO separate ring_hop_pack dispatches."""
    from repro.launch.train import make_shardmap_train_step
    calls = []
    real_lookup = dispatch.lookup

    def spy(op, backend):
        calls.append(op)
        return real_lookup(op, backend)

    monkeypatch.setattr(dispatch, "lookup", spy)
    model, opt, params, state, batch, flags = _setup(factor_wire="e4m3")
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    with compat.set_mesh(mesh):
        step = make_shardmap_train_step(model, opt, mesh,
                                        comm=make_comm_config("fused"))
        jax.jit(step).lower(params, state, batch, flags,
                            jnp.float32(1e-3), jnp.float32(5e-3),
                            jnp.float32(0.9))
    assert calls.count("factor_sum_wire") > 0, set(calls)
    assert calls.count("ring_hop_pack") == 0, set(calls)
    assert calls.count("ring_hop_unpack") > 0, set(calls)  # decode side


def test_accum_wire_guard():
    from repro.launch.train import make_train_step
    model, opt, *_ = _setup(factor_wire="e4m3")
    with pytest.raises(ValueError, match="accumulate wire-format"):
        make_train_step(model, opt, accum=2)
    make_train_step(model, opt, accum=1)      # fine without accumulation
    model_d, opt_d, *_ = _setup()
    make_train_step(model_d, opt_d, accum=2)  # dense capture accumulates


@needs_devices
def test_e2e_fused_matches_dense_20_steps():
    """Acceptance: 20-step fused-vs-dense loss parity under jit AND
    shard_map. Mesh (2, 4) so the layer axis scatters and every factor
    family's wire payload actually rides the all_to_all."""
    from repro.launch.train import make_shardmap_train_step, make_train_step
    losses = {}
    for label, wire, strat, sharded in (
            ("dense", "", "dense", True),
            ("fused", "e4m3", "fused", True),
            ("fused_jit", "e4m3", None, False)):
        model, opt, params, state, batch, flags = _setup(factor_wire=wire)
        if sharded:
            mesh = compat.make_mesh((2, 4), ("data", "model"))
            with compat.set_mesh(mesh):
                step = jax.jit(make_shardmap_train_step(
                    model, opt, mesh, comm=make_comm_config(strat)))
                out = []
                for _ in range(20):
                    params, state, m = step(params, state, batch, flags,
                                            1e-3, 5e-3, 0.9)
                    out.append(float(m["loss"]))
            assert step.reducer.replicated == []
        else:
            step = jax.jit(make_train_step(model, opt))
            out = []
            for _ in range(20):
                params, state, m = step(params, state, batch, flags,
                                        1e-3, 5e-3, 0.9)
                out.append(float(m["loss"]))
        losses[label] = out
    for label in ("fused", "fused_jit"):
        assert np.isfinite(losses[label]).all()
        assert losses[label][-1] < losses[label][0]          # it trains
        # fused quantizes the captured statistics themselves, so the
        # overfit fixture's bitwise chaos onsets a little earlier than the
        # ring_fp8 wire (~step 5, loss already < 0.1): pin the descent
        # prefix tightly, then require both runs to stay trained
        np.testing.assert_allclose(losses["dense"][:5], losses[label][:5],
                                   rtol=2e-2, atol=2e-2)
        assert max(losses[label][5:]) < 1.0
    assert max(losses["dense"][5:]) < 1.0


@needs_devices
def test_e2e_hier_matches_dense_20_steps():
    """Acceptance: 20-step hier-vs-dense loss parity on the simulated
    2-host x 4-device topology. Mesh (8, 1) with n_layers=8 so the layer
    axis scatters 8-ways and both hier levels run."""
    from repro.launch.train import make_shardmap_train_step
    mesh = compat.make_mesh((8, 1), ("data", "model"))
    losses = {}
    for strat in ("dense", "hier"):
        model, opt, params, state, batch, flags = _setup(n_layers=8)
        comm = make_comm_config(strat, devices_per_host=4)
        with compat.set_mesh(mesh):
            step = jax.jit(make_shardmap_train_step(model, opt, mesh,
                                                    comm=comm))
            out = []
            for _ in range(20):
                params, state, m = step(params, state, batch, flags,
                                        1e-3, 5e-3, 0.9)
                out.append(float(m["loss"]))
        losses[strat] = out
        # the 8-way scatter replicates the two nb=4 vocab-side stats
        # (genuinely indivisible — exact psum, so parity is unaffected);
        # every layer-stacked family must still scatter so both hier
        # levels actually run
        assert set(step.reducer.replicated) <= {"embed.g", "head.a"}
        assert not any(n.startswith("blk/") for n in step.reducer.replicated)
        if strat == "hier":
            rep = step.reducer.scatter_report()
            assert rep["hier_topology"] == {"devices_per_host": 4,
                                            "hosts": 2}
            levels = step.reducer.wire_bytes_per_stat_levels()
            assert any(inter > 0 for _, inter in levels.values())
    assert np.isfinite(losses["hier"]).all()
    assert losses["hier"][-1] < losses["hier"][0]
    # the inter-host leg fp8-rounds every refresh, so the overfit
    # fixture's bitwise chaos onsets once the loss is tiny (~step 4):
    # pin the descent prefix tightly, then require both runs to stay
    # trained for the remaining 16 steps
    np.testing.assert_allclose(losses["dense"][:4], losses["hier"][:4],
                               rtol=2e-2, atol=2e-2)
    assert max(losses["dense"][4:]) < 1.0
    assert max(losses["hier"][4:]) < 1.0
