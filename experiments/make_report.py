"""Generate the §Dry-run and §Roofline tables for EXPERIMENTS.md from
experiments/dryrun/*.json, plus the §Stage-3 comm-volume table from
experiments/comm_volume_bs*.csv.

Both input sets are gitignored build artifacts; when they are missing this
script says which command regenerates them instead of crashing or silently
printing empty tables.

    PYTHONPATH=src python experiments/make_report.py > experiments/report.md
"""
import csv
import glob
import json
import sys


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_s(x):
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def comm_volume_section():
    """§Stage-3 comm volume from the stale_reduction benchmark's CSVs."""
    files = sorted(glob.glob("experiments/comm_volume_bs*.csv"))
    if not files:
        print("### Stage-3 comm volume\n")
        print("_experiments/comm_volume_bs*.csv not found (gitignored); "
              "regenerate with `PYTHONPATH=src python -m benchmarks.run "
              "--only stale_reduction`._\n")
        return
    print("### Stage-3 comm volume (per-step refreshed bytes, "
          "Fig. 6 series totals)\n")
    print("| series | steps | stat bytes | wire dense | wire ring "
          "| wire ring_fp8 | fp8/dense |")
    print("|---|---|---|---|---|---|---|")
    level_rows, flat_only = [], []
    for path in files:
        with open(path) as f:
            rows = list(csv.DictReader(f))
        if not rows or "wire_dense" not in rows[0]:
            print(f"_{path} is from a pre-wire-column run; regenerate it._")
            continue
        tot = {k: sum(int(float(r[k])) for r in rows)
               for k in ("stat_bytes", "wire_dense", "wire_ring",
                         "wire_ring_fp8")}
        ratio = (tot["wire_ring_fp8"] / tot["wire_dense"]
                 if tot["wire_dense"] else float("nan"))
        name = path.split("/")[-1].removesuffix(".csv")
        print(f"| {name} | {len(rows)} | {fmt_bytes(tot['stat_bytes'])} "
              f"| {fmt_bytes(tot['wire_dense'])} "
              f"| {fmt_bytes(tot['wire_ring'])} "
              f"| {fmt_bytes(tot['wire_ring_fp8'])} | {ratio:.3f} |")
        if "wire_hier_intra" in rows[0]:
            level_rows.append(
                (name, sum(int(float(r["wire_hier_intra"])) for r in rows),
                 sum(int(float(r["wire_hier_inter"])) for r in rows),
                 tot["wire_dense"]))
        else:
            flat_only.append(name)
    print()
    print("#### Per-level (hier) wire bytes\n")
    if level_rows:
        print("| series | intra-host | inter-host | inter/dense |")
        print("|---|---|---|---|")
        for name, intra, inter, dense in level_rows:
            r = inter / dense if dense else float("nan")
            print(f"| {name} | {fmt_bytes(intra)} | {fmt_bytes(inter)} "
                  f"| {r:.3f} |")
        print("\n_Two-level `hier` split under the modelled 2-host x "
              "4-device scatter group: full-precision intra-host "
              "psum_scatter vs fp8 inter-host ring — the inter-host leg is "
              "the leg the hierarchy shrinks._\n")
    if flat_only:
        print(f"_{', '.join(flat_only)}: only flat strategies were run "
              "(no per-level wire columns in the ledger); regenerate with "
              "`PYTHONPATH=src python -m benchmarks.run --only "
              "stale_reduction` for the intra-/inter-host split._\n")


def stage4_section(ok):
    """§Stage-4 inversion distribution from the dry-run records' stage4
    reports (per-layer inverse timing + gather bytes, dryrun
    --inverse-sharding)."""
    print("### Stage-4 inversion distribution\n")
    recs = [r for r in ok if r.get("stage4", {}).get("stats")]
    if not recs:
        print("_No dry-run record carries a Stage-4 report (pre-PR-7 "
              "records, or no `--schedule shardmap` train case was run); "
              "regenerate with `PYTHONPATH=src python -m repro.launch.dryrun "
              "--schedule shardmap --inverse-sharding`._\n")
        return
    if not any(r["stage4"]["inverse_sharding"] for r in recs):
        print("_Only replicated Stage-4 runs exist (every device redundantly "
              "inverts every factor, gather bytes 0); rerun with "
              "`--inverse-sharding` for the sharded refresh numbers._\n")
    print("| arch | shape | mode | stat | layers | group | us/layer "
          "| us/dev repl | us/dev sharded | gather |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        s4 = r["stage4"]
        mode = "sharded" if s4["inverse_sharding"] else "replicated"
        for name, st in sorted(s4["stats"].items()):
            print(f"| {r['arch']} | {r['shape']} | {mode} | {name} "
                  f"| {st['layers']} | {st['group']} "
                  f"| {fmt_s(st['us_per_layer'] * 1e-6)} "
                  f"| {fmt_s(st['replicated_us_per_device'] * 1e-6)} "
                  f"| {fmt_s(st['sharded_us_per_device'] * 1e-6)} "
                  f"| {fmt_bytes(st['gather_bytes'])} |")
    print("\n_us/layer is a measured single-slice inversion with the "
          "configured method on the dry-run host; the per-device columns "
          "scale it by the layer count and the reducer's scatter group "
          "(ownership rule of `repro.comm.Stage4Inverter`). The gather "
          "column is the sym-packed f32 preconditioner all-gather per "
          "refresh — zero on replicated runs, which gather nothing._\n")


def overhead_section():
    """§Overhead accounting from a --metrics-jsonl event stream: the
    paper's decomposition of step time into forward/backward vs Stage-2/3/4
    (the "negligible overhead" claim, §5.2), amortized over the measured
    refresh frequency."""
    print("### Overhead accounting (per-step time decomposition)\n")
    files = sorted(glob.glob("experiments/metrics*.jsonl"))
    if not files:
        print("_experiments/metrics*.jsonl not found (gitignored); generate "
              "a stream with `PYTHONPATH=src python -m repro.launch.train "
              "--steps 20 --metrics-jsonl experiments/metrics.jsonl` and "
              "rerun._\n")
        return
    for path in files:
        events = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
        steps = [e for e in events if e["type"] == "step"]
        probes = [e for e in events if e["type"] == "probe"]
        cfgs = [e for e in events if e["type"] == "run_config"]
        name = path.split("/")[-1]
        if not steps:
            print(f"_{name}: no step events; not a training stream._\n")
            continue
        n = len(steps)
        refresh_steps = sum(1 for e in steps if e.get("kind") == "refresh")
        # chunked pipeline streams tag the trigger step "capture" instead of
        # "refresh" (no inline inversions run there) — both count toward the
        # refresh FREQUENCY the amortized column models
        capture_dts = [e["dt"] for e in steps
                       if e.get("kind") == "capture" and "dt" in e]
        n_capture = sum(1 for e in steps if e.get("kind") == "capture")
        r = (refresh_steps + n_capture) / n
        dts = sorted(e["dt"] for e in steps if "dt" in e)
        tag = ""
        if cfgs:
            c = cfgs[0]
            tag = (f" — `{c.get('arch', '?')}`, {c.get('steps', n)} steps, "
                   f"backend `{c.get('backend', '?')}`, "
                   f"inverse `{c.get('inverse_method', '?')}`")
        print(f"**{name}**{tag}: {n} steps, {refresh_steps} refreshed + "
              f"{n_capture} captured (r={r:.2f}), median step "
              f"{fmt_s(dts[len(dts) // 2]) if dts else 'n/a'}\n")

        # the overlapped column: measured per-step dt surcharges from a
        # chunked-pipeline stream (--refresh-chunks K>1). The capture step
        # carries Stage-2/3; each drain step carries one chunk of Stage-4.
        chunks_cfg = int(cfgs[0].get("refresh_chunks", 1)) if cfgs else 1
        idle_dts = [e["dt"] for e in steps
                    if e.get("kind") == "fast" and "dt" in e
                    and not e.get("refresh_inflight")]
        drain_dts = [e["dt"] for e in steps
                     if e.get("kind") == "fast" and "dt" in e
                     and e.get("refresh_inflight")]
        pipelined = bool(chunks_cfg > 1 and capture_dts
                         and (idle_dts or drain_dts))
        if not probes:
            print("_No probe event (run used --no-overhead-probe); the "
                  "decomposition needs the stage-isolated timings — rerun "
                  "without the flag._\n")
            continue
        p = probes[-1]
        fwd_bwd = p["fwd_bwd_us"]
        fast = p["fast_us"]
        refresh = p["refresh_us"]
        capture_delta = max(p["capture_us"] - fwd_bwd, 0.0)
        inverse = p["inverse_us"]
        apply_us = max(fast - fwd_bwd, 0.0)           # Stage-4 precond apply
        reduce_us = max(refresh - fast - capture_delta - inverse, 0.0)
        # modelled amortized step: every step pays fast, a fraction r also
        # pays the refresh surcharge
        total = fast + r * (refresh - fast)
        rows = [
            ("forward/backward", fwd_bwd, 1.0),
            ("Stage-4 precondition apply", apply_us, 1.0),
            ("Stage-2 capture (extra)", capture_delta, r),
            ("Stage-3 reduce + refresh residual", reduce_us, r),
            ("Stage-4 inverse", inverse, r),
        ]
        over = {}
        if pipelined:
            # measured amortized cost with the pipeline on: median capture /
            # drain step surcharge over the idle fast-step baseline, spread
            # at the measured capture frequency. The capture surcharge is
            # Stage-2+3 (split pro-rata by the probe's isolated timings);
            # the drain surcharge sum is the overlapped Stage-4 work.
            if idle_dts:
                base_dt = sorted(idle_dts)[len(idle_dts) // 2]
            else:
                # at the controller's minimum cadence every fast step drains
                # a chunk (no idle steps): approximate the pure-fast baseline
                # with the drain steps' lower decile (lightest chunk)
                base_dt = sorted(drain_dts)[max(0, len(drain_dts) // 10)]
            cap_med = sorted(capture_dts)[len(capture_dts) // 2]
            cap_sur = max(cap_med - base_dt, 0.0) * 1e6
            drain_sur = (sum(max(d - base_dt, 0.0) for d in drain_dts)
                         * 1e6 / len(capture_dts))
            r_cap = len(capture_dts) / n
            split = capture_delta + reduce_us
            s2_share = capture_delta / split if split else 0.5
            over = {
                "forward/backward": fwd_bwd,
                "Stage-4 precondition apply": apply_us,
                "Stage-2 capture (extra)": cap_sur * s2_share * r_cap,
                "Stage-3 reduce + refresh residual":
                    cap_sur * (1.0 - s2_share) * r_cap,
                "Stage-4 inverse": drain_sur * r_cap,
            }
        print("| component | isolated us | amortized us | overlapped us "
              "| % of step |")
        print("|---|---|---|---|---|")
        for label, us, freq in rows:
            am = us * freq
            ov = f"{over[label]:.0f}" if pipelined else "—"
            pct = 100.0 * am / total if total else 0.0
            print(f"| {label} | {us:.0f} | {am:.0f} | {ov} | {pct:.1f}% |")
        overhead = total - fwd_bwd
        print(f"\n_Modelled amortized step: {total:.0f}us; second-order "
              f"overhead over forward/backward: "
              f"{100.0 * overhead / fwd_bwd if fwd_bwd else 0.0:.1f}% "
              f"(the paper's negligible-overhead claim is this number "
              f"staying small as r shrinks under Algorithm 2). Isolated "
              f"timings are the run's probe event; r is measured from the "
              f"stream's refresh decisions; the Stage-3 row absorbs the "
              f"refresh-path residual the probe cannot split further._\n")
        if pipelined:
            ov_total = base_dt * 1e6 + r_cap * (cap_sur + drain_sur)
            print(f"_Overlapped (measured, --refresh-chunks "
                  f"{chunks_cfg}): idle fast step {base_dt * 1e6:.0f}us, "
                  f"capture surcharge {cap_sur:.0f}us, drained Stage-4 "
                  f"surcharge {drain_sur:.0f}us per refresh -> amortized "
                  f"step {ov_total:.0f}us. The overlapped column replaces "
                  f"the probe model with per-step dt deltas from the "
                  f"stream's capture/drain events._\n")
        else:
            print("_Overlapped column: this stream carries no pipelined "
                  "refresh (only inline-refresh runs present) — rerun with "
                  "`--refresh-chunks K>1` to measure the chunked Stage-4 "
                  "drain hidden behind the fast steps._\n")


def main():
    overhead_section()
    files = sorted(glob.glob("experiments/dryrun/*.json"))
    if not files:
        # still render the comm section (its CSV inputs are independent)
        # before failing with the regen instructions
        comm_volume_section()
        sys.exit(
            "make_report: no dry-run records in experiments/dryrun/ (the "
            "directory is gitignored). Generate them first with\n"
            "    PYTHONPATH=src python -m repro.launch.dryrun --all "
            "--mesh both --out experiments/dryrun")
    recs = [json.load(open(f)) for f in files]
    ok = [r for r in recs if r["status"] == "ok"]
    by = {(r["arch"], r["shape"], r["mesh"]): r for r in ok}

    print("### Dry-run matrix (lower + compile success)\n")
    archs = sorted({r["arch"] for r in ok})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    print("| arch | " + " | ".join(shapes) + " |")
    print("|---" * (len(shapes) + 1) + "|")
    for a in archs:
        cells = []
        for s in shapes:
            single = (a, s, "16x16") in by
            multi = (a, s, "2x16x16") in by
            cells.append("ok+ok" if single and multi else
                         f"{'ok' if single else 'FAIL'}+{'ok' if multi else 'FAIL'}")
        print(f"| {a} | " + " | ".join(cells) + " |")
    print(f"\n{len(ok)}/80 (arch x shape x mesh) combinations compile "
          "(single-pod 16x16 = 256 chips AND multi-pod 2x16x16 = 512 chips).\n")

    print("### Per-case detail (single-pod, bytes/device from "
          "memory_analysis, collective schedule)\n")
    print("| arch | shape | label | args/dev | temps/dev | AG | AR | RS | A2A | CP |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        if r["mesh"] != "16x16":
            continue
        ma = r.get("memory_analysis", {})
        args = fmt_bytes(ma.get("argument_size_in_bytes", 0))
        temp = fmt_bytes(ma.get("temp_size_in_bytes", 0))
        cb = r["collective_by_kind"]
        print(f"| {r['arch']} | {r['shape']} | {r['label']} | {args} | {temp} "
              f"| {fmt_bytes(cb['all-gather'])} | {fmt_bytes(cb['all-reduce'])} "
              f"| {fmt_bytes(cb['reduce-scatter'])} | {fmt_bytes(cb['all-to-all'])} "
              f"| {fmt_bytes(cb['collective-permute'])} |")

    print("\n### Roofline (single-pod 16x16, 256 chips; trip-weighted HLO "
          "analysis; TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print("| arch | shape | compute | memory | collective | bottleneck | "
          "MODEL_FLOPS | MODEL/HLO |")
    print("|---|---|---|---|---|---|---|---|")
    for r in ok:
        if r["mesh"] != "16x16":
            continue
        print(f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} "
              f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
              f"| **{r['bottleneck']}** | {r['model_flops']:.3g} "
              f"| {r['useful_flops_ratio']:.3f} |")

    print("\n### Multi-pod (2x16x16) deltas\n")
    print("| arch | shape | coll 16x16 | coll 2x16x16 | ratio |")
    print("|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r1 = by.get((a, s, "16x16"))
            r2 = by.get((a, s, "2x16x16"))
            if r1 and r2 and r1["collective_bytes"]:
                ratio = r2["collective_bytes"] / r1["collective_bytes"]
                print(f"| {a} | {s} | {fmt_bytes(r1['collective_bytes'])} "
                      f"| {fmt_bytes(r2['collective_bytes'])} | {ratio:.2f}x |")

    print()
    stage4_section(ok)
    comm_volume_section()


if __name__ == "__main__":
    main()
