"""Generate the §Dry-run and §Roofline tables for EXPERIMENTS.md from
experiments/dryrun/*.json.

    PYTHONPATH=src python experiments/make_report.py > experiments/report.md
"""
import glob
import json


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_s(x):
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def main():
    recs = [json.load(open(f))
            for f in sorted(glob.glob("experiments/dryrun/*.json"))]
    ok = [r for r in recs if r["status"] == "ok"]
    by = {(r["arch"], r["shape"], r["mesh"]): r for r in ok}

    print("### Dry-run matrix (lower + compile success)\n")
    archs = sorted({r["arch"] for r in ok})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    print("| arch | " + " | ".join(shapes) + " |")
    print("|---" * (len(shapes) + 1) + "|")
    for a in archs:
        cells = []
        for s in shapes:
            single = (a, s, "16x16") in by
            multi = (a, s, "2x16x16") in by
            cells.append("ok+ok" if single and multi else
                         f"{'ok' if single else 'FAIL'}+{'ok' if multi else 'FAIL'}")
        print(f"| {a} | " + " | ".join(cells) + " |")
    print(f"\n{len(ok)}/80 (arch x shape x mesh) combinations compile "
          "(single-pod 16x16 = 256 chips AND multi-pod 2x16x16 = 512 chips).\n")

    print("### Per-case detail (single-pod, bytes/device from "
          "memory_analysis, collective schedule)\n")
    print("| arch | shape | label | args/dev | temps/dev | AG | AR | RS | A2A | CP |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        if r["mesh"] != "16x16":
            continue
        ma = r.get("memory_analysis", {})
        args = fmt_bytes(ma.get("argument_size_in_bytes", 0))
        temp = fmt_bytes(ma.get("temp_size_in_bytes", 0))
        cb = r["collective_by_kind"]
        print(f"| {r['arch']} | {r['shape']} | {r['label']} | {args} | {temp} "
              f"| {fmt_bytes(cb['all-gather'])} | {fmt_bytes(cb['all-reduce'])} "
              f"| {fmt_bytes(cb['reduce-scatter'])} | {fmt_bytes(cb['all-to-all'])} "
              f"| {fmt_bytes(cb['collective-permute'])} |")

    print("\n### Roofline (single-pod 16x16, 256 chips; trip-weighted HLO "
          "analysis; TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print("| arch | shape | compute | memory | collective | bottleneck | "
          "MODEL_FLOPS | MODEL/HLO |")
    print("|---|---|---|---|---|---|---|---|")
    for r in ok:
        if r["mesh"] != "16x16":
            continue
        print(f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} "
              f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
              f"| **{r['bottleneck']}** | {r['model_flops']:.3g} "
              f"| {r['useful_flops_ratio']:.3f} |")

    print("\n### Multi-pod (2x16x16) deltas\n")
    print("| arch | shape | coll 16x16 | coll 2x16x16 | ratio |")
    print("|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r1 = by.get((a, s, "16x16"))
            r2 = by.get((a, s, "2x16x16"))
            if r1 and r2 and r1["collective_bytes"]:
                ratio = r2["collective_bytes"] / r1["collective_bytes"]
                print(f"| {a} | {s} | {fmt_bytes(r1['collective_bytes'])} "
                      f"| {fmt_bytes(r2['collective_bytes'])} | {ratio:.2f}x |")


if __name__ == "__main__":
    main()
