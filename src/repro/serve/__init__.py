"""Serving stack: decode-time KV caching and continuous batching.

The training side of the repo reproduces the paper's SP-NGD optimizer; this
package is the inference side the ROADMAP north-star implies ("heavy traffic
from millions of users"). It builds on the same kernel substrate:

* :class:`ServeConfig` — the knobs (ring vs dense cache, fp8 vs f32 payload,
  kernel backend) threaded through ``DecoderLM.init_cache / prefill /
  decode_step``.
* :mod:`repro.serve.cache` — ring-buffer KV cache layout helpers and byte
  accounting (fp8 e4m3 payload + per-row f32 scales via ``repro.quant``).
* :class:`ContinuousBatcher` — slot-based continuous batching over
  variable-length requests driving one jitted decode step.

The decode hot path is the ``swa_decode`` kernel op
(``repro.kernels.dispatch``): single-query flash attention over the cache,
dequantizing fp8 payloads on read in VMEM.
"""

from repro.serve.cache import cache_bytes, ring_capacity
from repro.serve.config import ServeConfig
from repro.serve.scheduler import ContinuousBatcher, Request

__all__ = ["ServeConfig", "ContinuousBatcher", "Request", "cache_bytes",
           "ring_capacity"]
