"""Continuous batching over a slot-based serving cache.

The batcher owns a fixed pool of ``slots`` cache lanes and keeps them busy:
requests are admitted into free slots as they arrive (a batch-1 prefill
scattered into the packed cache), every active slot advances one token per
jitted decode step over the WHOLE batch, and slots free up the moment their
request finishes — no waiting for the longest sequence in a static batch.
Per-sequence state (absolute position, ring-slot occupancy) lives in the
cache's per-slot ``len`` vector, so sequences at different depths coexist in
one decode step.

Inactive slots still ride through the batched step (their lanes compute on
stale state) — that is the standard continuous-batching trade: the step is
one fixed-shape jit, and a wasted lane costs less than a recompile. Their
outputs are discarded.

Prefill bucketing
-----------------
Prompts pad to the next power-of-two bucket, so the prefill jit cache holds
O(log max_len) programs instead of one per distinct prompt length. Padding
rides AFTER the prompt, which keeps it invisible end to end: causal masking
means the real positions' logits never see the pad tokens, the jitted
prefill overrides the sub-cache ``len`` to the TRUE length so decode resumes
at the right position, and the junk the pad positions wrote into cache
slots ``[s, S_b)`` is masked by the position contract (a slot is only
visible once decode reaches its position — by which point decode has
overwritten it with the real token). The one hazard is the ring: a bucket
larger than the cache capacity would wrap pad writes over REAL keys still
inside the window, so those prompts fall back to an exact-shape prefill
(``bucket_prompts=False`` disables bucketing entirely).

Sampling
--------
``temperature > 0`` switches the decode step from argmax to temperature /
top-k sampling with one PRNG stream per request (``fold_in(seed, uid)``,
then one split per generated token), so a request's tokens depend only on
``(seed, uid, prompt, max_new)`` — never on slot assignment or admission
order. ``temperature == 0`` (the default) keeps the pre-sampling greedy
program exactly: no keys are threaded through the step, and outputs are
bit-identical to the greedy batcher regardless of ``seed``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.config import ServeConfig


@dataclasses.dataclass
class Request:
    """One decode request: prompt token ids + how many tokens to generate."""
    prompt: np.ndarray
    max_new: int
    uid: int = 0


@dataclasses.dataclass
class _Slot:
    uid: int
    remaining: int
    out: list


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _sample(key, logits, temperature: float, top_k: int):
    """Temperature / top-k sample one token id from a ``(V,)`` logit row.
    ``top_k == 0`` means no truncation; ``top_k == 1`` reduces to argmax
    (the masking keeps only the max before the categorical draw)."""
    l = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(l, top_k)[0][-1]
        l = jnp.where(l < kth, -jnp.inf, l)
    return jax.random.categorical(key, l).astype(jnp.int32)


class ContinuousBatcher:
    """Continuous batcher over ``model`` with ``slots`` cache lanes of
    ``max_len`` tokens each. Greedy by default; ``temperature``/``top_k``
    enable per-request seeded sampling (see module docstring)."""

    def __init__(self, model, params, serve: ServeConfig, *, slots: int,
                 max_len: int, temperature: float = 0.0, top_k: int = 0,
                 seed: int = 0, bucket_prompts: bool = True):
        if temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        self.model = model
        self.params = params
        self.serve = serve
        self.slots = slots
        self.max_len = max_len
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.bucket_prompts = bucket_prompts
        self.cache = model.init_cache(slots, max_len, serve=serve)
        self.tokens = np.zeros((slots,), np.int32)   # next input per lane
        self.active: list[Optional[_Slot]] = [None] * slots
        self._prefill = {}           # bucketed prompt length -> jitted prefill
        self._base_key = jax.random.PRNGKey(seed)
        self._keys = jax.random.split(self._base_key, slots)  # per-lane carry

        if self.temperature == 0.0:
            # static greedy branch: the exact pre-sampling program, no keys
            @functools.partial(jax.jit, donate_argnums=(1,))
            def step(params, cache, tokens):
                logits, cache = model.decode_step(params, cache, tokens,
                                                  serve=serve)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
        else:
            temp, tk = self.temperature, self.top_k

            @functools.partial(jax.jit, donate_argnums=(1,))
            def step(params, cache, tokens, keys):
                logits, cache = model.decode_step(params, cache, tokens,
                                                  serve=serve)
                split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
                tok = jax.vmap(lambda k, l: _sample(k, l, temp, tk))(
                    split[:, 0], logits)
                return tok, cache, split[:, 1]

        self._step = step

    # ------------------------------------------------------------------
    # slot admission / eviction
    # ------------------------------------------------------------------

    def free_slots(self) -> list:
        return [i for i, s in enumerate(self.active) if s is None]

    def admit(self, req: Request) -> int:
        """Prefill ``req`` into a free slot; returns the slot index."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot (call step() until one drains)")
        slot = free[0]
        prompt = np.asarray(req.prompt, np.int32).reshape(1, -1)
        s = prompt.shape[1]
        cap = self.cache["k"].shape[2]               # ring capacity / max_len
        sb = _next_pow2(s) if self.bucket_prompts else s
        if sb > cap:
            sb = s    # pad writes past capacity would wrap over real keys
        if sb != s:
            prompt = np.pad(prompt, ((0, 0), (0, sb - s)))
        if sb not in self._prefill:
            def _prefill_fn(params, batch, true_len):
                logits, sub = self.model.prefill(params, batch,
                                                 max_len=self.max_len,
                                                 serve=self.serve)
                # decode resumes at the TRUE length, not the bucket
                sub = {**sub, "len": jnp.full_like(sub["len"], true_len)}
                return logits[0, true_len - 1], sub
            self._prefill[sb] = jax.jit(_prefill_fn)
        last, sub = self._prefill[sb](self.params,
                                      {"tokens": jnp.asarray(prompt)},
                                      jnp.int32(s))
        self.cache = _scatter(self.cache, sub, slot)
        if self.temperature == 0.0:
            first = int(jnp.argmax(last))
        else:
            key = jax.random.fold_in(self._base_key, req.uid)
            key, sub_key = jax.random.split(key)
            first = int(_sample(sub_key, last, self.temperature, self.top_k))
            self._keys = self._keys.at[slot].set(key)
        self.tokens[slot] = first
        self.active[slot] = _Slot(uid=req.uid, remaining=req.max_new - 1,
                                  out=[first])
        return slot

    def step(self) -> dict:
        """One batched decode step; returns {uid: finished token list} for
        requests that completed on this step."""
        if self.temperature == 0.0:
            next_tok, self.cache = self._step(self.params, self.cache,
                                              jnp.asarray(self.tokens))
        else:
            next_tok, self.cache, self._keys = self._step(
                self.params, self.cache, jnp.asarray(self.tokens), self._keys)
        next_tok = np.asarray(next_tok)
        done = {}
        for i, st in enumerate(self.active):
            if st is None:
                continue
            if st.remaining > 0:
                st.out.append(int(next_tok[i]))
                st.remaining -= 1
                self.tokens[i] = next_tok[i]
            if st.remaining <= 0:
                done[st.uid] = st.out
                self.active[i] = None
        return done

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------

    def run(self, requests: list) -> dict:
        """Serve ``requests`` to completion; returns {uid: generated ids}.
        Admission is greedy: every free slot is filled from the queue before
        each step, so finished lanes are reused immediately."""
        queue = list(requests)
        results: dict = {}
        while queue or any(s is not None for s in self.active):
            while queue and self.free_slots():
                self.admit(queue.pop(0))
            results.update(self.step())
        return results


def _scatter(cache: dict, sub: dict, slot: int) -> dict:
    """Write a batch-1 prefill cache into lane ``slot`` of the packed cache.
    KV arrays carry (L, B, ...) — batch is axis 1; ``len`` is (B,)."""
    out = {}
    for k, v in cache.items():
        axis = 0 if k == "len" else 1
        out[k] = jax.lax.dynamic_update_slice_in_dim(
            v, sub[k].astype(v.dtype), slot, axis=axis)
    return out
