"""Continuous batching over a slot-based serving cache.

The batcher owns a fixed pool of ``slots`` cache lanes and keeps them busy:
requests are admitted into free slots as they arrive (a batch-1 prefill
scattered into the packed cache), every active slot advances one token per
jitted decode step over the WHOLE batch, and slots free up the moment their
request finishes — no waiting for the longest sequence in a static batch.
Per-sequence state (absolute position, ring-slot occupancy) lives in the
cache's per-slot ``len`` vector, so sequences at different depths coexist in
one decode step.

Inactive slots still ride through the batched step (their lanes compute on
stale state) — that is the standard continuous-batching trade: the step is
one fixed-shape jit, and a wasted lane costs less than a recompile. Their
outputs are discarded.

Prefill jits once per distinct prompt length (documented trade-off: exact
shapes beat padding for the short prompt distributions the benchs use; a
production stack would bucket lengths).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.config import ServeConfig


@dataclasses.dataclass
class Request:
    """One decode request: prompt token ids + how many tokens to generate."""
    prompt: np.ndarray
    max_new: int
    uid: int = 0


@dataclasses.dataclass
class _Slot:
    uid: int
    remaining: int
    out: list


class ContinuousBatcher:
    """Greedy-decoding continuous batcher over ``model`` with ``slots``
    cache lanes of ``max_len`` tokens each."""

    def __init__(self, model, params, serve: ServeConfig, *, slots: int,
                 max_len: int):
        self.model = model
        self.params = params
        self.serve = serve
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len, serve=serve)
        self.tokens = np.zeros((slots,), np.int32)   # next input per lane
        self.active: list[Optional[_Slot]] = [None] * slots
        self._prefill = {}           # prompt length -> jitted prefill

        @functools.partial(jax.jit, donate_argnums=(1,))
        def step(params, cache, tokens):
            logits, cache = model.decode_step(params, cache, tokens,
                                              serve=serve)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._step = step

    # ------------------------------------------------------------------
    # slot admission / eviction
    # ------------------------------------------------------------------

    def free_slots(self) -> list:
        return [i for i, s in enumerate(self.active) if s is None]

    def admit(self, req: Request) -> int:
        """Prefill ``req`` into a free slot; returns the slot index."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot (call step() until one drains)")
        slot = free[0]
        prompt = np.asarray(req.prompt, np.int32).reshape(1, -1)
        s = prompt.shape[1]
        if s not in self._prefill:
            self._prefill[s] = jax.jit(functools.partial(
                self.model.prefill, max_len=self.max_len, serve=self.serve))
        logits, sub = self._prefill[s](self.params,
                                       {"tokens": jnp.asarray(prompt)})
        self.cache = _scatter(self.cache, sub, slot)
        first = int(jnp.argmax(logits[0, -1]))
        self.tokens[slot] = first
        self.active[slot] = _Slot(uid=req.uid, remaining=req.max_new - 1,
                                  out=[first])
        return slot

    def step(self) -> dict:
        """One batched decode step; returns {uid: finished token list} for
        requests that completed on this step."""
        next_tok, self.cache = self._step(self.params, self.cache,
                                          jnp.asarray(self.tokens))
        next_tok = np.asarray(next_tok)
        done = {}
        for i, st in enumerate(self.active):
            if st is None:
                continue
            if st.remaining > 0:
                st.out.append(int(next_tok[i]))
                st.remaining -= 1
                self.tokens[i] = next_tok[i]
            if st.remaining <= 0:
                done[st.uid] = st.out
                self.active[i] = None
        return done

    # ------------------------------------------------------------------
    # driver
    # ------------------------------------------------------------------

    def run(self, requests: list) -> dict:
        """Serve ``requests`` to completion; returns {uid: generated ids}.
        Admission is greedy: every free slot is filled from the queue before
        each step, so finished lanes are reused immediately."""
        queue = list(requests)
        results: dict = {}
        while queue or any(s is not None for s in self.active):
            while queue and self.free_slots():
                self.admit(queue.pop(0))
            results.update(self.step())
        return results


def _scatter(cache: dict, sub: dict, slot: int) -> dict:
    """Write a batch-1 prefill cache into lane ``slot`` of the packed cache.
    KV arrays carry (L, B, ...) — batch is axis 1; ``len`` is (B,)."""
    out = {}
    for k, v in cache.items():
        axis = 0 if k == "len" else 1
        out[k] = jax.lax.dynamic_update_slice_in_dim(
            v, sub[k].astype(v.dtype), slot, axis=axis)
    return out
