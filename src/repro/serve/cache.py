"""Ring-buffer KV cache layout helpers.

Layout (per layer, batch b, capacity C, KV heads, head dim hd):

* payload ``k``/``v``: (L, b, C, KV, hd) — fp8 (e4m3/e5m2) or f32. The
  quantization row is one (token, KV head) vector over hd, so dequant needs
  exactly one multiply per cache row — the same ``repro.quant`` row codec
  the optimizer uses for factor storage/wire.
* scales ``k_scale``/``v_scale``: (L, b, C, KV) f32 (fp8 payloads only).
* ``len``: (b,) i32 — each sequence's absolute decode position (== tokens
  cached). Token at position p lives in slot ``p % C``; the visibility
  contract is pinned in ``repro.kernels.ref.swa_decode_slot_positions``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ring_capacity(window: int, max_len: int) -> int:
    """Slots the ring needs: the window, but never more than the sequence
    budget (a window longer than ``max_len`` can't fill past max_len)."""
    if window <= 0:
        raise ValueError("ring cache needs window > 0 (window=0 is full "
                         "causal: use the dense layout)")
    return min(window, max_len)


def encode_rows(x: jax.Array, fmt: str | None, scale_mode: str):
    """Quantize cache rows (..., hd) to (payload, scale (...,)) via the
    ``repro.quant`` row codec; ``fmt=None`` stores f32 with no scale."""
    if fmt is None:
        return x.astype(jnp.float32), None
    from repro.quant import quant
    return quant.quantize_rows(x.astype(jnp.float32), fmt, scale_mode)


def write_slot(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Write one decode step into per-sequence ring slots.

    cache (b, C, ...), new (b, 1, ...), slot (b,) i32 — each sequence lands
    in its own slot (``pos % C``), so the update is a vmapped
    dynamic_update_slice over the batch axis."""
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0)
    )(cache, new, slot)


def prefill_gather_index(seq_len: int, capacity: int) -> np.ndarray:
    """Source position feeding each ring slot after prefilling ``seq_len``
    tokens: the latest position p <= seq_len - 1 with ``p % capacity == s``
    (the state ``seq_len`` sequential ring writes would leave). Slots no
    position maps to (seq_len < capacity) come out NEGATIVE — the caller
    zero-fills them; the position contract masks them as unwritten."""
    s = np.arange(capacity)
    return s + capacity * ((seq_len - 1 - s) // capacity)


def cache_bytes(cache: dict) -> int:
    """Total KV-cache bytes (payload + scales; excludes non-KV state)."""
    total = 0
    for key in ("k", "v", "k_scale", "v_scale"):
        if key in cache:
            a = cache[key]
            total += int(np.prod(a.shape)) * a.dtype.itemsize
    return total
