"""Serving configuration: the knobs behind the decode-path cache switch."""

from __future__ import annotations

import dataclasses

KV_CACHES = ("dense", "ring")
KV_DTYPES = ("f32", "fp8_e4m3", "fp8_e5m2")

# kv_dtype knob -> repro.quant format name (None = no quantization)
_QUANT_FMT = {"f32": None, "fp8_e4m3": "e4m3", "fp8_e5m2": "e5m2"}


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Decode-path serving knobs (``DecoderLM.init_cache/prefill/decode_step``).

    kv_cache: ``"ring"`` sizes the per-layer KV cache to the attention
      window (capacity ``min(window, max_len)`` slots, token at position p
      in slot ``p % capacity``) and decodes through the single-query
      ``swa_decode`` flash kernel; ``"dense"`` keeps the seed's
      ``max_len``-padded cache. A ring cache with ``window == 0`` (full
      causal — every past token visible, nothing evictable) silently
      degrades to the dense-f32 layout.
    kv_dtype: cache payload storage. ``"fp8_e4m3"``/``"fp8_e5m2"`` store the
      fp8 payload plus one f32 scale per (token, KV head) row — the
      ``repro.quant`` row codec — and the decode kernel dequantizes on read
      in VMEM; ``"f32"`` stores dense f32. fp8 requires the ring cache (the
      dense fallback path reads through the jnp attention which has no
      dequant hook).
    scale_mode: per-row scale representation (``"fp32"`` | ``"pow2"``),
      forwarded to ``repro.quant.quantize_rows``.
    window: sliding-window override; ``None`` inherits
      ``ArchConfig.sliding_window``, ``0`` forces full-causal (and thereby
      the dense cache).
    backend: kernel backend for the decode attention (``"ref"`` |
      ``"pallas"`` | ``"auto"``); ``None`` inherits ``ArchConfig.backend``.
    """

    kv_cache: str = "ring"
    kv_dtype: str = "fp8_e4m3"
    scale_mode: str = "fp32"
    window: int | None = None
    backend: str | None = None

    def __post_init__(self):
        if self.kv_cache not in KV_CACHES:
            raise ValueError(f"unknown kv_cache {self.kv_cache!r}; expected "
                             f"{KV_CACHES}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {self.kv_dtype!r}; expected "
                             f"{KV_DTYPES}")
        if self.kv_dtype != "f32" and self.kv_cache != "ring":
            raise ValueError("fp8 KV payloads need kv_cache='ring' (the "
                             "dense path has no dequant-on-read hook)")

    @property
    def quant_fmt(self) -> str | None:
        """``repro.quant`` format name for the payload (None = unquantized)."""
        return _QUANT_FMT[self.kv_dtype]

    def resolved_window(self, cfg) -> int:
        """Effective sliding window for an :class:`ArchConfig`."""
        return cfg.sliding_window if self.window is None else self.window

    def is_ring(self, cfg) -> bool:
        """Whether the ring layout is actually in effect (window > 0)."""
        return self.kv_cache == "ring" and self.resolved_window(cfg) > 0
