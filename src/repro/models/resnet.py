"""Small ResNet (CIFAR-scale) — the paper-faithful substrate.

The paper trains ResNet-50/ImageNet; this scaled-down ResNet exercises the
*exact* technique set at CPU-testable scale: conv-layer K-FAC via im2col
(Eq. 10-11), BatchNorm scale/bias with unit-wise 2x2 Fisher (Eq. 15-17),
running mixup + random erasing (§6.1), polynomial decay + coupled momentum
(§6.2), and weight norm rescaling (§6.3). BatchNorm uses in-batch statistics
(no moving averages) as in the large-batch training literature the paper
builds on.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tagging
from repro.core.fisher import SiteInfo
from repro.core.tagging import FactorSpec


@dataclasses.dataclass(frozen=True)
class ConvNetConfig:
    n_classes: int = 10
    widths: tuple = (16, 32, 64)
    blocks_per_stage: int = 2
    in_channels: int = 3
    kfac_max_dim: int = 2048
    bn_fisher: str = "unit"      # "unit" (Eq. 15) | "full" (Fig. 5 baseline)


def _batchnorm(x, gamma, beta, stats, eps=1e-5):
    mu = x.mean((0, 1, 2), keepdims=True)
    var = x.var((0, 1, 2), keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + eps)
    return tagging.scale_bias_site(xhat, gamma, beta, stats, spatial=2)


class ConvNet:
    def __init__(self, cfg: ConvNetConfig = ConvNetConfig()):
        self.cfg = cfg
        self.spec = FactorSpec(max_dim=cfg.kfac_max_dim)

    # ---- init ----

    def init(self, key) -> dict:
        cfg = self.cfg
        from repro.models.layers import he_normal
        params = {}
        k0, key = jax.random.split(key)
        params["stem"] = {
            "w": he_normal(k0, (3, 3, cfg.in_channels, cfg.widths[0]),
                           fan_in=9 * cfg.in_channels),
            "gamma": jnp.ones(cfg.widths[0]), "beta": jnp.zeros(cfg.widths[0])}
        c_in = cfg.widths[0]
        for si, w in enumerate(cfg.widths):
            for bi in range(cfg.blocks_per_stage):
                name = f"s{si}b{bi}"
                k1, k2, k3, key = jax.random.split(key, 4)
                stride = 2 if (bi == 0 and si > 0) else 1
                blk = {
                    "w1": he_normal(k1, (3, 3, c_in, w), fan_in=9 * c_in),
                    "g1": jnp.ones(w), "b1": jnp.zeros(w),
                    "w2": he_normal(k2, (3, 3, w, w), fan_in=9 * w),
                    "g2": jnp.ones(w), "b2": jnp.zeros(w),
                }
                if stride != 1 or c_in != w:
                    blk["wskip"] = he_normal(k3, (1, 1, c_in, w), fan_in=c_in)
                params[name] = blk
                c_in = w
        kh, key = jax.random.split(key)
        params["head"] = {"w": he_normal(kh, (c_in, cfg.n_classes))}
        return params

    # ---- forward ----

    def forward(self, params, x, fstats=None):
        cfg = self.cfg
        g = lambda n: (fstats.get(n) if fstats else None)
        h = tagging.conv_site(x, params["stem"]["w"], g("stem_w"),
                              spec=self.spec)
        h = _batchnorm(h, params["stem"]["gamma"], params["stem"]["beta"],
                       g("stem_bn"))
        h = jax.nn.relu(h)
        c_in = cfg.widths[0]
        for si, w in enumerate(cfg.widths):
            for bi in range(cfg.blocks_per_stage):
                name = f"s{si}b{bi}"
                p = params[name]
                stride = 2 if (bi == 0 and si > 0) else 1
                y = tagging.conv_site(h, p["w1"], g(f"{name}_w1"),
                                      stride=stride, spec=self.spec)
                y = _batchnorm(y, p["g1"], p["b1"], g(f"{name}_bn1"))
                y = jax.nn.relu(y)
                y = tagging.conv_site(y, p["w2"], g(f"{name}_w2"),
                                      spec=self.spec)
                y = _batchnorm(y, p["g2"], p["b2"], g(f"{name}_bn2"))
                if "wskip" in p:
                    h = tagging.conv_site(h, p["wskip"], g(f"{name}_wskip"),
                                          stride=stride, spec=self.spec)
                h = jax.nn.relu(h + y)
                c_in = w
        h = h.mean((1, 2))                          # global average pool
        logits = tagging.dense_site(h, params["head"]["w"], g("head"),
                                    self.spec)
        return logits

    def loss(self, params, fstats, batch):
        logits = self.forward(params, batch["images"], fstats)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        if labels.ndim == 1:                        # hard labels
            nll = -jnp.take_along_axis(logp, labels[:, None], -1).mean()
        else:                                       # soft labels (mixup)
            nll = -(labels * logp).sum(-1).mean()
        return nll, {"logits": logits}

    # ---- SP-NGD wiring ----

    def site_infos(self) -> dict[str, SiteInfo]:
        cfg = self.cfg
        infos = {
            "stem_w": SiteInfo("conv", "stem/w", 9 * cfg.in_channels,
                               cfg.widths[0], self.spec, ksize=3),
            "stem_bn": SiteInfo("scale_bias", "stem/gamma", cfg.widths[0],
                                cfg.widths[0], beta_param="stem/beta"),
            "head": SiteInfo("dense", "head/w", cfg.widths[-1],
                             cfg.n_classes, self.spec),
        }
        c_in = cfg.widths[0]
        for si, w in enumerate(cfg.widths):
            for bi in range(cfg.blocks_per_stage):
                nm = f"s{si}b{bi}"
                infos[f"{nm}_w1"] = SiteInfo("conv", f"{nm}/w1", 9 * c_in, w,
                                             self.spec, ksize=3)
                infos[f"{nm}_bn1"] = SiteInfo("scale_bias", f"{nm}/g1", w, w,
                                              beta_param=f"{nm}/b1")
                infos[f"{nm}_w2"] = SiteInfo("conv", f"{nm}/w2", 9 * w, w,
                                             self.spec, ksize=3)
                infos[f"{nm}_bn2"] = SiteInfo("scale_bias", f"{nm}/g2", w, w,
                                              beta_param=f"{nm}/b2")
                if (bi == 0 and si > 0) or c_in != w:
                    infos[f"{nm}_wskip"] = SiteInfo("conv", f"{nm}/wskip",
                                                    c_in, w, self.spec,
                                                    ksize=1)
                c_in = w
        return infos

    def fstats(self) -> dict:
        full = self.cfg.bn_fisher == "full"
        out = {}
        for fam, info in self.site_infos().items():
            if info.kind in ("dense", "conv"):
                out[fam] = tagging.make_stats(info.spec, info.d_in,
                                              info.d_out, lead=info.lead)
            elif info.kind == "scale_bias":
                out[fam] = tagging.make_scale_bias_stats(info.d_out,
                                                         lead=info.lead,
                                                         full=full)
        return out

    def site_counts(self, batch) -> dict:
        """Conv sites: n_a = B*Ho*Wo (im2col tokens), n_g = B (samples)."""
        b, hh, ww, _ = batch["images"].shape
        counts = {}
        c_in = self.cfg.widths[0]
        # stem at full resolution
        counts["stem_w"] = (b * hh * ww, b)
        counts["stem_bn"] = (b, b)
        res = {0: (hh, ww)}
        h, w_ = hh, ww
        for si, w in enumerate(self.cfg.widths):
            for bi in range(self.cfg.blocks_per_stage):
                nm = f"s{si}b{bi}"
                if bi == 0 and si > 0:
                    h, w_ = -(-h // 2), -(-w_ // 2)
                counts[f"{nm}_w1"] = (b * h * w_, b)
                counts[f"{nm}_bn1"] = (b, b)
                counts[f"{nm}_w2"] = (b * h * w_, b)
                counts[f"{nm}_bn2"] = (b, b)
                counts[f"{nm}_wskip"] = (b * h * w_, b)
        counts["head"] = (b, b)
        return {k: v for k, v in counts.items() if k in self.fstats()}
