"""Config-driven decoder-only LM covering all assigned architecture families:
dense GQA (llama/qwen/nemotron/musicgen/llava backbones), MoE (mixtral,
qwen2-moe), hybrid attention+SSM (hymba), and RWKV-6.

Layers are homogeneous and stacked: parameters and K-FAC factor-statistics
arrays carry a leading (L,) axis and the forward is a ``lax.scan`` over
layers — this is what turns the paper's ragged ReduceScatterV into uniform
factor-family collectives (DESIGN.md §2).

Model surface used by the rest of the framework:
  init(key) -> params
  loss(params, fstats, batch) -> (loss, aux)        # train step objective
  forward(params, batch, fstats) -> (logits, aux)   # prefill
  init_cache(batch, max_len) / decode_step(params, cache, tokens)
  site_infos() / fstats() / site_counts(batch)      # SP-NGD wiring
  input_specs(shape) -> ShapeDtypeStruct batch      # dry-run stand-ins
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.core import tagging
from repro.core.fisher import SiteInfo
from repro.core.tagging import FactorSpec
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import he_normal, rmsnorm, layernorm, apply_rope
from repro.models.mlp import mlp, init_mlp


class DecoderLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        # Optional residual-stream sharding constraint between layers
        # (Megatron-style sequence parallelism; set by the launch layer).
        self.act_hook = None
        # Optional MoE dispatch-buffer sharding constraint (launch layer).
        self.moe_hook = None
        self.spec = FactorSpec(max_dim=cfg.kfac_max_dim, backend=cfg.backend,
                               wire_fmt=cfg.factor_wire)
        self.head_spec = FactorSpec(g_kind=cfg.head_g_kind,
                                    max_dim=cfg.kfac_max_dim,
                                    backend=cfg.backend,
                                    wire_fmt=cfg.factor_wire)
        self.embed_spec = FactorSpec(a_kind="diag", g_kind="full",
                                     max_dim=cfg.kfac_max_dim,
                                     backend=cfg.backend,
                                     wire_fmt=cfg.factor_wire)
        self.specs = self._block_site_specs()

    def _tp_spec(self, d_in: int, d_out: int, *, a_tp: bool = False,
                 g_tp: bool = False) -> FactorSpec:
        """Factor spec with blocks aligned to TP shard boundaries
        (cfg.tp_shards > 0): the side whose activation is model-sharded gets
        block size = dim/tp so factor construction never crosses shards."""
        cfg = self.cfg
        tp = cfg.tp_shards

        def aligned(dim: int) -> int:
            """Largest block size that divides the shard width (dim/tp) and
            fits under kfac_max_dim — blocks must never cross shards."""
            if dim % tp or dim // tp < cfg.min_block:
                return 0
            b = dim // tp
            while b > cfg.kfac_max_dim:
                for k in (2, 3, 5, 7):
                    if b % k == 0:
                        b //= k
                        break
                else:
                    return 0            # no usable divisor
            return b if b >= cfg.min_block else 0

        a_max = aligned(d_in) if (tp and a_tp) else 0
        g_max = aligned(d_out) if (tp and g_tp) else 0
        return FactorSpec(max_dim=cfg.kfac_max_dim, a_max=a_max, g_max=g_max,
                          backend=cfg.backend, wire_fmt=cfg.factor_wire)

    def _spec_sub(self, prefix: str) -> dict:
        return {k[len(prefix):]: v for k, v in self.specs.items()
                if k.startswith(prefix)}

    def _block_site_specs(self) -> dict:
        """Per-site FactorSpec for block-level sites (module-local names).
        Column-parallel matmuls have model-sharded OUTPUTS (g side);
        row-parallel matmuls have model-sharded INPUTS (a side)."""
        cfg = self.cfg
        d, h, kv, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.hd, cfg.d_ff)
        s: dict = {}
        if cfg.block_type in ("dense", "moe", "hymba"):
            s["attn_wq"] = self._tp_spec(d, h * hd, g_tp=True)
            s["attn_wk"] = self._tp_spec(d, kv * hd, g_tp=True)
            s["attn_wv"] = self._tp_spec(d, kv * hd, g_tp=True)
            s["attn_wo"] = self._tp_spec(h * hd, d, a_tp=True)
        if cfg.block_type in ("dense", "hymba"):
            s["mlp_up"] = self._tp_spec(d, ff, g_tp=True)
            s["mlp_gate"] = s["mlp_up"]
            s["mlp_down"] = self._tp_spec(ff, d, a_tp=True)
        if cfg.block_type == "moe":
            s["moe_router"] = self.spec
            s["moe_we_up"] = self._tp_spec(d, ff, g_tp=True)
            s["moe_we_gate"] = s["moe_we_up"]
            s["moe_we_down"] = self._tp_spec(ff, d, a_tp=True)
            sf = cfg.n_shared_experts * ff
            s["moe_sh_up"] = self._tp_spec(d, sf, g_tp=True)
            s["moe_sh_gate"] = s["moe_sh_up"]
            s["moe_sh_down"] = self._tp_spec(sf, d, a_tp=True)
        if cfg.block_type == "hymba":
            di = cfg.ssm_expand * d
            dt_rank = max(1, d // 16)
            s["ssm_in_proj"] = self._tp_spec(d, 2 * di, g_tp=True)
            s["ssm_xdb"] = self._tp_spec(di, dt_rank + 2 * cfg.ssm_state,
                                         a_tp=True)
            s["ssm_dt_proj"] = self._tp_spec(dt_rank, di, g_tp=True)
            s["ssm_out_proj"] = self._tp_spec(di, d, a_tp=True)
        if cfg.block_type == "rwkv":
            for nm in ("tm_wr", "tm_wk", "tm_wv", "tm_wg"):
                s[nm] = self._tp_spec(d, d, g_tp=True)
            s["tm_wo"] = self._tp_spec(d, d, a_tp=True)
            s["tm_w_lora_a"] = self.spec
            s["tm_w_lora_b"] = self.spec
            s["cm_wk"] = self._tp_spec(d, cfg.d_ff, g_tp=True)
            s["cm_wv"] = self._tp_spec(cfg.d_ff, d, a_tp=True)
            s["cm_wr"] = self._tp_spec(d, d, g_tp=True)
        return s

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        ke, kb, kn, kh, kp = jax.random.split(key, 5)
        params = {
            "embed": {"table": (jax.random.normal(ke, (cfg.vocab, cfg.d_model))
                                * 0.02).astype(cfg.dtype)},
            "final_norm": {"gamma": jnp.ones((cfg.d_model,), jnp.float32)},
            "head": {"w": he_normal(kh, (cfg.d_model, cfg.vocab), cfg.dtype)},
        }
        if cfg.frontend == "vision":
            params["proj"] = {"w": he_normal(kp, (cfg.frontend_dim, cfg.d_model),
                                             cfg.dtype)}
        keys = jax.random.split(kb, cfg.n_layers)
        per_layer = [self._init_block(k) for k in keys]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
        return params

    def _init_block(self, key: jax.Array) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        p: dict = {"ln1": {"gamma": jnp.ones((cfg.d_model,), jnp.float32)},
                   "ln2": {"gamma": jnp.ones((cfg.d_model,), jnp.float32)}}
        if cfg.norm == "layernorm":
            p["ln1"]["beta"] = jnp.zeros((cfg.d_model,), jnp.float32)
            p["ln2"]["beta"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cfg.block_type in ("dense", "moe", "hymba"):
            p["attn"] = self._init_attn(ks[0])
        if cfg.block_type in ("dense", "hymba"):
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                                cfg.dtype)
        if cfg.block_type == "moe":
            p["moe"] = moe_lib.init_moe(ks[2], cfg.d_model, cfg.d_ff,
                                        cfg.n_experts, cfg.n_shared_experts,
                                        cfg.dtype)
        if cfg.block_type == "hymba":
            p["ssm"] = ssm_lib.init_ssm(ks[3], cfg.d_model, cfg.ssm_state,
                                        cfg.dtype, expand=cfg.ssm_expand)
        if cfg.block_type == "rwkv":
            p.pop("ln1"); p.pop("ln2")
            p["ln1"] = {"gamma": jnp.ones((cfg.d_model,), jnp.float32),
                        "beta": jnp.zeros((cfg.d_model,), jnp.float32)}
            p["ln2"] = {"gamma": jnp.ones((cfg.d_model,), jnp.float32),
                        "beta": jnp.zeros((cfg.d_model,), jnp.float32)}
            p["tm"] = rwkv_lib.init_rwkv_tm(ks[4], cfg.d_model, cfg.hd,
                                            cfg.dtype)
            p["cm"] = rwkv_lib.init_rwkv_cm(ks[5], cfg.d_model, cfg.d_ff,
                                            cfg.dtype)
        return p

    def _init_attn(self, key: jax.Array) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
        p = {"wq": he_normal(ks[0], (d, h * hd), cfg.dtype),
             "wk": he_normal(ks[1], (d, kv * hd), cfg.dtype),
             "wv": he_normal(ks[2], (d, kv * hd), cfg.dtype),
             "wo": he_normal(ks[3], (h * hd, d), cfg.dtype)}
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((h * hd,), cfg.dtype)
            p["bk"] = jnp.zeros((kv * hd,), cfg.dtype)
            p["bv"] = jnp.zeros((kv * hd,), cfg.dtype)
        return p

    # ------------------------------------------------------------------
    # norms / attention helpers
    # ------------------------------------------------------------------

    def _norm(self, x, p, fs_key, fs):
        stats = fs.get(fs_key) if fs else None
        if "beta" in p:
            return layernorm(x, p["gamma"], p["beta"], stats)
        return rmsnorm(x, p["gamma"], stats)

    def _attn(self, x, p, fs, *, positions, cache_kv=None, cache_len=None,
              window=None, serve=None):
        cfg = self.cfg
        b, s, d = x.shape
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        g = lambda n: (fs.get(f"attn_{n}") if fs else None)
        sp = self.specs
        q = tagging.dense_site(x, p["wq"], g("wq"), sp["attn_wq"])
        k = tagging.dense_site(x, p["wk"], g("wk"), sp["attn_wk"])
        v = tagging.dense_site(x, p["wv"], g("wv"), sp["attn_wv"])
        if cfg.qkv_bias:
            q = tagging.bias_site(q, p["bq"], g("bq"))
            k = tagging.bias_site(k, p["bk"], g("bk"))
            v = tagging.bias_site(v, p["bv"], g("bv"))
        q = q.reshape(b, s, h, hd)
        k = k.reshape(b, s, kv, hd)
        v = v.reshape(b, s, kv, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        win = cfg.sliding_window if window is None else window
        if cache_kv is not None and serve is not None:
            win = serve.resolved_window(cfg)
            out, new_cache = self._attn_serve(q, k, v, cache_kv, cache_len,
                                              serve, win)
        elif cache_kv is not None:
            ck, cv = cache_kv["k"], cache_kv["v"]     # (B, M, KV, hd)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                     cache_len, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                     cache_len, axis=1)
            m = ck.shape[1]
            if s == 1 and win and win < m:
                # decode-span clamp: a windowed query sees at most `win`
                # keys, so slice that span out of the max_len-padded cache
                # instead of streaming (and masking) all m slots. start is
                # clamped so the slice stays in bounds before the window
                # fills; q_offset/kv_len are re-based into the slice, which
                # keeps the mask identical to the unclamped call.
                start = jnp.clip(cache_len + 1 - win, 0, m - win)
                cks = jax.lax.dynamic_slice_in_dim(ck, start, win, axis=1)
                cvs = jax.lax.dynamic_slice_in_dim(cv, start, win, axis=1)
                out = attn_lib.attention(q, cks, cvs, causal=True, window=win,
                                         q_offset=cache_len - start,
                                         kv_len=cache_len + 1 - start,
                                         backend=cfg.backend)
            else:
                out = attn_lib.attention(q, ck, cv, causal=True, window=win,
                                         q_offset=cache_len,
                                         kv_len=cache_len + s,
                                         backend=cfg.backend)
            new_cache = {"k": ck, "v": cv}
        else:
            # k/v stay at kv heads (unexpanded): the kernel-eligible route
            # keeps them per-KV-head all the way into the Pallas kernels
            # (GQA layout contract, see repro.kernels.dispatch); the chunked
            # ref path expands inside attention()
            out = attn_lib.attention(q, k, v, causal=True, window=win,
                                     backend=cfg.backend)
            new_cache = None
        o = tagging.dense_site(out.reshape(b, s, h * hd), p["wo"], g("wo"),
                               sp["attn_wo"])
        return o, new_cache

    def _attn_serve(self, q, k, v, cache_kv, cache_len, serve, win):
        """Serving cache paths (``repro.serve``): ring buffer sized to the
        window (fp8 or f32 payload) or the dense-f32 ``window=0`` fallback,
        both decoding through the single-query ``swa_decode`` flash op.

        q (B, S, H, hd); k/v (B, S, KV, hd); cache payload (B, C, KV, hd)
        [+ (B, C, KV) scales for fp8]; cache_len (B,) i32 per-sequence
        positions. S > 1 is prefill (full windowed attention over the
        prompt, then pack the last C tokens into their ring slots); S == 1
        is one decode step (write the token's k/v into slot ``pos % C``,
        then flash-decode over the cache). Returns (out, new_cache)."""
        from repro.kernels import dispatch
        from repro.serve import cache as cache_lib
        cfg = self.cfg
        b, s, h, hd = q.shape
        kv = k.shape[2]
        ck, cv = cache_kv["k"], cache_kv["v"]
        cap = ck.shape[1]
        ring = serve.is_ring(cfg)
        fmt = serve.quant_fmt if ring else None
        backend = serve.backend or cfg.backend
        # the kernel's ring contract needs C == window; the dense fallback
        # (full causal) passes window=0 and masks on position <= pos
        kern_win = cap if ring else 0

        if s > 1:
            out = attn_lib.attention(q, k, v, causal=True, window=win,
                                     backend=backend)
            # pack the cache tail: slot s' receives the latest prompt
            # position p <= S-1 with p % C == s' (negative = unwritten)
            idx = cache_lib.prefill_gather_index(s, cap)
            live = jnp.asarray(idx >= 0)[None, :, None, None]
            sel = jnp.asarray(idx.clip(min=0), jnp.int32)
            gk = jnp.where(live, k[:, sel], 0.0)
            gv = jnp.where(live, v[:, sel], 0.0)
            kp, ks = cache_lib.encode_rows(gk, fmt, serve.scale_mode)
            vp, vs = cache_lib.encode_rows(gv, fmt, serve.scale_mode)
            new_cache = {"k": kp.astype(ck.dtype), "v": vp.astype(cv.dtype)}
            if ks is not None:
                new_cache["k_scale"] = ks
                new_cache["v_scale"] = vs
            return out, new_cache

        # decode: write this token, then flash-decode over the cache
        kp, ks = cache_lib.encode_rows(k, fmt, serve.scale_mode)
        vp, vs = cache_lib.encode_rows(v, fmt, serve.scale_mode)
        slot = (cache_len % cap).astype(jnp.int32)
        ck = cache_lib.write_slot(ck, kp.astype(ck.dtype), slot)
        cv = cache_lib.write_slot(cv, vp.astype(cv.dtype), slot)
        new_cache = {"k": ck, "v": cv}
        ksg = vsg = None
        if ks is not None:
            cks = cache_lib.write_slot(cache_kv["k_scale"], ks, slot)
            cvs = cache_lib.write_slot(cache_kv["v_scale"], vs, slot)
            new_cache["k_scale"] = cks
            new_cache["v_scale"] = cvs
            ksg = cks.transpose(0, 2, 1).reshape(b * kv, cap)
            vsg = cvs.transpose(0, 2, 1).reshape(b * kv, cap)
        # GQA kernel layout (query head c*G + r under KV head c, same
        # grouping as models.attention._to_kernel_layout)
        qg = q[:, 0].reshape(b, kv, h // kv, hd).reshape(b * kv, h // kv, hd)
        kg = ck.transpose(0, 2, 1, 3).reshape(b * kv, cap, hd)
        vg = cv.transpose(0, 2, 1, 3).reshape(b * kv, cap, hd)
        pos = jnp.repeat(cache_len.astype(jnp.int32), kv)
        og = dispatch.swa_decode(qg, kg, vg, pos, window=kern_win,
                                 k_scale=ksg, v_scale=vsg, backend=backend)
        out = og.reshape(b, h, hd)[:, None].astype(q.dtype)
        return out, new_cache

    # ------------------------------------------------------------------
    # block (shared by train forward and decode, cache optional)
    # ------------------------------------------------------------------

    def _block(self, x, p, fs, *, positions, cache=None, cache_len=None,
               serve=None):
        """Returns (y, aux_loss, new_cache)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_cache = {}
        if cfg.block_type == "rwkv":
            h1 = self._norm(x, p["ln1"], "ln1", fs)
            tm_kwargs = {}
            if cache is not None:
                tm_kwargs = dict(last_x=cache["tm_x"], wkv_state=cache["wkv"])
            tm_out = rwkv_lib.time_mix(h1, p["tm"],
                                       _sub(fs, "tm_"), head_dim=cfg.hd,
                                       spec=self.spec,
                                       specs=self._spec_sub("tm_"),
                                       chunk=cfg.scan_chunk,
                                       return_state=cache is not None,
                                       **tm_kwargs)
            if cache is not None:
                tm_out, (new_last, new_wkv) = tm_out
                new_cache["tm_x"] = new_last
                new_cache["wkv"] = new_wkv
            x = x + tm_out
            h2 = self._norm(x, p["ln2"], "ln2", fs)
            cm_kwargs = {}
            if cache is not None:
                cm_kwargs = dict(last_x=cache["cm_x"])
            cm_out = rwkv_lib.channel_mix(h2, p["cm"], _sub(fs, "cm_"),
                                          spec=self.spec,
                                          specs=self._spec_sub("cm_"),
                                          return_state=cache is not None,
                                          **cm_kwargs)
            if cache is not None:
                cm_out, new_cm_x = cm_out
                new_cache["cm_x"] = new_cm_x
            x = x + cm_out
            return x, aux, new_cache

        h1 = self._norm(x, p["ln1"], "ln1", fs)
        kv_sub = (_kv_cache_sub(cache) if cache is not None else None)
        if cfg.block_type == "hymba":
            attn_out, kvc = self._attn(h1, p["attn"], fs, positions=positions,
                                       cache_kv=kv_sub, cache_len=cache_len,
                                       serve=serve)
            ssm_kwargs = {}
            if cache is not None:
                ssm_kwargs = dict(init_state=cache["ssm_h"],
                                  conv_cache=cache["conv"])
            ssm_out = ssm_lib.ssm_branch(h1, p["ssm"], _sub(fs, "ssm_"),
                                         state=cfg.ssm_state, spec=self.spec,
                                         specs=self._spec_sub("ssm_"),
                                         chunk=cfg.scan_chunk,
                                         return_state=cache is not None,
                                         **ssm_kwargs)
            if cache is not None:
                ssm_out, (new_h, new_conv) = ssm_out
                new_cache.update(ssm_h=new_h, conv=new_conv, **kvc)
            # parallel heads: average the two branch outputs (Hymba-style)
            x = x + 0.5 * (attn_out + ssm_out)
        else:
            attn_out, kvc = self._attn(h1, p["attn"], fs, positions=positions,
                                       cache_kv=kv_sub, cache_len=cache_len,
                                       serve=serve)
            if cache is not None:
                new_cache.update(kvc)
            x = x + attn_out

        h2 = self._norm(x, p["ln2"], "ln2", fs)
        if cfg.block_type == "moe":
            y, aux = moe_lib.moe_block(
                h2, p["moe"], _sub(fs, "moe_"), n_experts=cfg.n_experts,
                top_k=cfg.top_k, act=cfg.act,
                capacity_factor=cfg.capacity_factor, spec=self.spec,
                specs=self._spec_sub("moe_"), buf_hook=self.moe_hook)
            x = x + y
        else:
            x = x + mlp(h2, p["mlp"], _sub(fs, "mlp_"), act=cfg.act,
                        gated=cfg.gated_mlp, spec=self.spec,
                        specs=self._spec_sub("mlp_"))
        return x, aux, new_cache

    # ------------------------------------------------------------------
    # embedding / frontend
    # ------------------------------------------------------------------

    def _embed_inputs(self, params, batch, fs):
        """Returns (h (B, S_total, d), positions (S_total,), text_start)."""
        cfg = self.cfg
        tok = batch["tokens"]
        h_text = tagging.embed_site(tok, params["embed"]["table"],
                                    fs.get("embed") if fs else None,
                                    self.embed_spec)
        if cfg.frontend == "vision":
            pe = batch["pixel_embeds"].astype(cfg.dtype)  # (B, Tf, fd)
            img = tagging.dense_site(pe, params["proj"]["w"],
                                     fs.get("proj") if fs else None, self.spec)
            h = jnp.concatenate([img, h_text], axis=1)
            n_front = pe.shape[1]
        else:
            h = h_text
            n_front = 0
        positions = jnp.arange(h.shape[1])
        return h, positions, n_front

    # ------------------------------------------------------------------
    # forward / loss
    # ------------------------------------------------------------------

    def forward(self, params, batch, fstats=None):
        cfg = self.cfg
        h, positions, n_front = self._embed_inputs(params, batch, fstats)
        fs_blk = _blk_stats(fstats)

        def body(carry, xs):
            x, aux = carry
            if fs_blk is None:
                p = xs
                fs_l = None
            else:
                p, fs_l = xs
            y, a, _ = self._block(x, p, fs_l, positions=positions)
            if self.act_hook is not None:
                y = self.act_hook(y)
            return (y, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        xs = params["blocks"] if fs_blk is None else (params["blocks"], fs_blk)
        (h, aux_loss), _ = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                                        xs)
        h = self._norm(h, params["final_norm"], "final_norm", fstats)
        logits = tagging.dense_site(h, params["head"]["w"],
                                    fstats.get("head") if fstats else None,
                                    self.head_spec)
        return logits, {"aux_loss": aux_loss / cfg.n_layers,
                        "n_front": n_front}

    def loss(self, params, fstats, batch):
        cfg = self.cfg
        logits, aux = self.forward(params, batch, fstats)
        n_front = aux["n_front"]
        if n_front:
            logits_text = logits[:, n_front:, :]
        else:
            logits_text = logits
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits_text.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("mask")
        if mask is not None:
            denom = jnp.maximum(mask.sum(), 1.0)
            loss = (nll * mask).sum() / denom
        else:
            loss = nll.mean()
        total = loss + cfg.aux_loss_coef * aux["aux_loss"]
        return total, {"logits": logits_text, "nll": loss,
                       "aux_loss": aux["aux_loss"]}

    # ------------------------------------------------------------------
    # serving: cache init / prefill / single-token decode
    # ------------------------------------------------------------------

    def init_cache(self, batch_size: int, max_len: int,
                   dtype=None, *, serve=None) -> dict:
        cfg = self.cfg
        dtype = dtype or cfg.dtype
        L, b = cfg.n_layers, batch_size
        if serve is not None:
            return self._init_serve_cache(b, max_len, serve)
        c: dict = {"len": jnp.zeros((), jnp.int32)}
        if cfg.block_type in ("dense", "moe", "hymba"):
            kvshape = (L, b, max_len, cfg.n_kv_heads, cfg.hd)
            c["k"] = jnp.zeros(kvshape, dtype)
            c["v"] = jnp.zeros(kvshape, dtype)
        if cfg.block_type == "hymba":
            di = cfg.ssm_expand * cfg.d_model
            c["ssm_h"] = jnp.zeros((L, b, di, cfg.ssm_state), jnp.float32)
            c["conv"] = jnp.zeros((L, b, 3, di), dtype)
        if cfg.block_type == "rwkv":
            h = cfg.d_model // cfg.hd
            c["tm_x"] = jnp.zeros((L, b, 1, cfg.d_model), dtype)
            c["cm_x"] = jnp.zeros((L, b, 1, cfg.d_model), dtype)
            c["wkv"] = jnp.zeros((L, b, h, cfg.hd, cfg.hd), jnp.float32)
        return c

    def _init_serve_cache(self, b: int, max_len: int, serve) -> dict:
        """Serving cache (``repro.serve``): ring buffer sized to the window
        (fp8 payload + per-row f32 scales, or f32), or the dense-f32
        fallback when the resolved window is 0 (full causal — nothing is
        evictable, so a ring cannot be smaller than max_len anyway).
        ``len`` is a per-sequence (B,) position vector so the continuous
        batcher can hold sequences at different depths in one cache."""
        from repro.serve import cache as cache_lib
        cfg = self.cfg
        if cfg.block_type not in ("dense", "moe"):
            raise NotImplementedError(
                f"serve caches cover attention-only blocks (dense/moe); "
                f"got block_type={cfg.block_type!r}")
        win = serve.resolved_window(cfg)
        ring = serve.is_ring(cfg)
        if not ring and win:
            raise ValueError(
                "serve kv_cache='dense' supports window == 0 only (a "
                "windowed dense decode belongs to the legacy serve=None "
                "path or the ring cache)")
        cap = cache_lib.ring_capacity(win, max_len) if ring else max_len
        L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        c: dict = {"len": jnp.zeros((b,), jnp.int32)}
        fmt = serve.quant_fmt if ring else None
        if fmt is None:
            c["k"] = jnp.zeros((L, b, cap, kvh, hd), jnp.float32)
            c["v"] = jnp.zeros((L, b, cap, kvh, hd), jnp.float32)
        else:
            from repro.quant import quant
            pdt = quant.FORMATS[fmt]
            c["k"] = jnp.zeros((L, b, cap, kvh, hd), pdt)
            c["v"] = jnp.zeros((L, b, cap, kvh, hd), pdt)
            c["k_scale"] = jnp.zeros((L, b, cap, kvh), jnp.float32)
            c["v_scale"] = jnp.zeros((L, b, cap, kvh), jnp.float32)
        return c

    def decode_step(self, params, cache, tokens: jax.Array, *, serve=None):
        """tokens: (B,) -> (logits (B, V), new_cache). One decode position.

        With ``serve`` (a :class:`repro.serve.ServeConfig`) the cache is the
        serving layout from :meth:`init_cache` — per-sequence ``len`` (B,),
        ring/fp8 payloads — and attention runs the ``swa_decode`` flash op;
        without it, the seed's dense-cache path (scalar ``len``)."""
        cfg = self.cfg
        h = tagging.embed_site(tokens[:, None], params["embed"]["table"],
                               None, self.embed_spec)
        pos = cache["len"]
        if serve is not None:
            positions = pos[:, None]               # (B, 1) per-seq rope
        else:
            positions = pos + jnp.arange(1)

        layer_cache = {k: v for k, v in cache.items() if k != "len"}

        def body(x, xs):
            p, c = xs
            y, _, new_c = self._block(x, p, None, positions=positions,
                                      cache=c, cache_len=pos, serve=serve)
            return y, new_c

        h, new_layer_cache = jax.lax.scan(body, h,
                                          (params["blocks"], layer_cache))
        h = self._norm(h, params["final_norm"], "final_norm", None)
        logits = tagging.dense_site(h, params["head"]["w"], None,
                                    self.head_spec)
        new_cache = dict(new_layer_cache)
        new_cache["len"] = pos + 1
        return logits[:, 0, :], new_cache

    def prefill(self, params, batch, max_len: int, *, serve=None):
        """Forward + cache fill (used by the serving example)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = self.init_cache(b, max_len, serve=serve)
        h, positions, n_front = self._embed_inputs(params, batch, None)

        layer_cache = {k: v for k, v in cache.items() if k != "len"}
        len0 = (jnp.zeros((b,), jnp.int32) if serve is not None
                else jnp.zeros((), jnp.int32))

        def body(x, xs):
            p, c = xs
            y, _, new_c = self._block(x, p, None, positions=positions,
                                      cache=c, cache_len=len0, serve=serve)
            return y, new_c

        h, new_layer_cache = jax.lax.scan(body, h,
                                          (params["blocks"], layer_cache))
        h = self._norm(h, params["final_norm"], "final_norm", None)
        logits = tagging.dense_site(h, params["head"]["w"], None,
                                    self.head_spec)
        cache = dict(new_layer_cache)
        slen = jnp.asarray(h.shape[1], jnp.int32)
        cache["len"] = (jnp.full((b,), slen) if serve is not None else slen)
        return logits, cache

    # ------------------------------------------------------------------
    # SP-NGD wiring: site registry, factor templates, token counts
    # ------------------------------------------------------------------

    def site_infos(self) -> dict[str, SiteInfo]:
        cfg = self.cfg
        L = (cfg.n_layers,)
        d, h, kv, hd, ff, v = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, cfg.d_ff, cfg.vocab)
        infos: dict[str, SiteInfo] = {
            "embed": SiteInfo("embed", "embed/table", v, d, self.embed_spec),
            "head": SiteInfo("dense", "head/w", d, v, self.head_spec),
            "final_norm": SiteInfo("scale_bias", "final_norm/gamma", d, d),
        }
        if cfg.frontend == "vision":
            infos["proj"] = SiteInfo("dense", "proj/w", cfg.frontend_dim, d,
                                     self.spec)

        def blk(name, kind, path, d_in, d_out, spec=None, lead=L, beta=None):
            eff = spec or self.specs.get(name, self.spec)
            infos[f"blk/{name}"] = SiteInfo(kind, f"blocks/{path}", d_in,
                                            d_out, eff,
                                            lead=lead, beta_param=beta)

        norm_beta = ("blocks/ln1/beta" if cfg.norm == "layernorm"
                     or cfg.block_type == "rwkv" else None)
        blk("ln1", "scale_bias", "ln1/gamma", d, d,
            beta="blocks/ln1/beta" if norm_beta else None)
        blk("ln2", "scale_bias", "ln2/gamma", d, d,
            beta="blocks/ln2/beta" if norm_beta else None)

        if cfg.block_type in ("dense", "moe", "hymba"):
            blk("attn_wq", "dense", "attn/wq", d, h * hd)
            blk("attn_wk", "dense", "attn/wk", d, kv * hd)
            blk("attn_wv", "dense", "attn/wv", d, kv * hd)
            blk("attn_wo", "dense", "attn/wo", h * hd, d)
            if cfg.qkv_bias:
                blk("attn_bq", "bias", "attn/bq", 0, h * hd)
                blk("attn_bk", "bias", "attn/bk", 0, kv * hd)
                blk("attn_bv", "bias", "attn/bv", 0, kv * hd)
        if cfg.block_type in ("dense", "hymba"):
            blk("mlp_up", "dense", "mlp/up", d, ff)
            if cfg.gated_mlp:
                blk("mlp_gate", "dense", "mlp/gate", d, ff)
            blk("mlp_down", "dense", "mlp/down", ff, d)
        if cfg.block_type == "moe":
            E = cfg.n_experts
            blk("moe_router", "dense", "moe/router", d, E)
            blk("moe_we_up", "grouped", "moe/we_up", d, ff, lead=L + (E,))
            blk("moe_we_gate", "grouped", "moe/we_gate", d, ff, lead=L + (E,))
            blk("moe_we_down", "grouped", "moe/we_down", ff, d, lead=L + (E,))
            if cfg.n_shared_experts:
                sf = cfg.n_shared_experts * ff
                blk("moe_sh_up", "dense", "moe/sh_up", d, sf)
                blk("moe_sh_gate", "dense", "moe/sh_gate", d, sf)
                blk("moe_sh_down", "dense", "moe/sh_down", sf, d)
        if cfg.block_type == "hymba":
            di = cfg.ssm_expand * d
            dt_rank = max(1, d // 16)
            blk("ssm_in_proj", "dense", "ssm/in_proj", d, 2 * di)
            blk("ssm_xdb", "dense", "ssm/xdb", di, dt_rank + 2 * cfg.ssm_state)
            blk("ssm_dt_proj", "dense", "ssm/dt_proj", dt_rank, di)
            blk("ssm_out_proj", "dense", "ssm/out_proj", di, d)
        if cfg.block_type == "rwkv":
            lora_r = 32
            for nm in ("wr", "wk", "wv", "wg", "wo"):
                blk(f"tm_{nm}", "dense", f"tm/{nm}", d, d)
            blk("tm_w_lora_a", "dense", "tm/w_lora_a", d, lora_r)
            blk("tm_w_lora_b", "dense", "tm/w_lora_b", lora_r, d)
            for nm in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"):
                blk(f"tm_{nm}", "scale_bias", f"tm/{nm}", d, d)
            blk("tm_ln_scale", "scale_bias", "tm/ln_scale", d, d)
            blk("cm_wk", "dense", "cm/wk", d, ff)
            blk("cm_wv", "dense", "cm/wv", ff, d)
            blk("cm_wr", "dense", "cm/wr", d, d)
            blk("cm_cm_mu_k", "scale_bias", "cm/mu_k", d, d)
            blk("cm_cm_mu_r", "scale_bias", "cm/mu_r", d, d)
        return infos

    def fstats(self) -> dict:
        """Zero factor-statistic accumulators, flat {family: stats}."""
        out = {}
        for fam, info in self.site_infos().items():
            if info.kind in ("dense", "grouped"):
                out[fam] = tagging.make_stats(info.spec, info.d_in, info.d_out,
                                              lead=info.lead)
            elif info.kind == "embed":
                out[fam] = tagging.make_embed_stats(info.d_in, info.d_out,
                                                    info.spec, lead=info.lead)
            elif info.kind == "bias":
                out[fam] = tagging.make_bias_stats(info.d_out, lead=info.lead)
            elif info.kind == "scale_bias":
                out[fam] = tagging.make_scale_bias_stats(info.d_out,
                                                         lead=info.lead)
        return out

    def site_counts(self, batch) -> dict:
        cfg = self.cfg
        tok = batch["tokens"]
        b = tok.shape[0]
        s_text = tok.shape[1] if tok.ndim > 1 else 1
        n_front = cfg.frontend_tokens if cfg.frontend == "vision" else 0
        n_total = b * (s_text + n_front)
        mask = batch.get("mask")
        n_loss = mask.sum() if mask is not None else jnp.asarray(
            b * s_text, jnp.float32)
        counts = {}
        for fam in self.fstats():
            if fam == "embed":
                counts[fam] = (b * s_text, n_loss)
            elif fam == "proj":
                counts[fam] = (b * n_front, n_loss)
            else:
                counts[fam] = (n_total, n_loss)
        return counts

    # ------------------------------------------------------------------
    # dry-run input stand-ins
    # ------------------------------------------------------------------

    def input_specs(self, shape: InputShape) -> dict:
        """ShapeDtypeStruct batch for lowering (no allocation)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
            if cfg.frontend == "vision":
                batch["pixel_embeds"] = sds((b, cfg.frontend_tokens,
                                             cfg.frontend_dim), jnp.bfloat16)
            return batch
        if shape.kind == "prefill":
            batch = {"tokens": sds((b, s), i32)}
            if cfg.frontend == "vision":
                batch["pixel_embeds"] = sds((b, cfg.frontend_tokens,
                                             cfg.frontend_dim), jnp.bfloat16)
            return batch
        # decode: one token against a cache of length s
        cache = jax.eval_shape(lambda: self.init_cache(b, s))
        return {"tokens": sds((b,), i32), "cache": cache}


def _kv_cache_sub(cache: dict) -> dict:
    """KV-cache entries of a layer cache (payloads + optional fp8 scales)."""
    return {k: cache[k] for k in ("k", "v", "k_scale", "v_scale")
            if k in cache}


def _sub(fs: Optional[dict], prefix: str) -> Optional[dict]:
    """Sub-view of a block's stats dict by key prefix."""
    if fs is None:
        return None
    return {k[len(prefix):]: v for k, v in fs.items() if k.startswith(prefix)}


def _blk_stats(fstats: Optional[dict]) -> Optional[dict]:
    """Block families ("blk/<name>") -> scan xs dict {"<name>": stats}."""
    if fstats is None:
        return None
    return {k[4:]: v for k, v in fstats.items() if k.startswith("blk/")}
