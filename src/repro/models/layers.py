"""Shared building blocks: norms, rotary embeddings, initializers."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tagging


def he_normal(key, shape, dtype=jnp.float32, fan_in: Optional[int] = None):
    """HeNormal (paper §7 uses Chainer's HeNormal default)."""
    fi = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = (2.0 / fi) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rmsnorm(x: jax.Array, gamma: jax.Array, stats: Optional[dict],
            eps: float = 1e-6) -> jax.Array:
    """RMSNorm with the scale tagged unit-wise (1x1 Fisher), mirroring the
    paper's unit-wise treatment of normalization parameters."""
    xf = x.astype(jnp.float32)
    xhat = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    xhat = xhat.astype(x.dtype)
    return tagging.scale_bias_site(xhat, gamma.astype(x.dtype), None, stats)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              stats: Optional[dict], eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    xhat = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return tagging.scale_bias_site(xhat, gamma.astype(x.dtype),
                                   beta.astype(x.dtype), stats)


def rope_freqs(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e4) -> jax.Array:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if ang.ndim == 2:                                   # (S, hd/2) -> broadcast B, H
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:                                               # (B, S, hd/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    x1, x2 = x[..., ::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                                  # nemotron squared-ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)
