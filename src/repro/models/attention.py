"""GQA attention with chunked (flash-style) online softmax, sliding-window
support, and a KV-cache decode path.

The chunked implementation never materializes the (Sq, Sk) score matrix —
it scans KV chunks with a running (max, denominator, accumulator) triple.
This is the pure-JAX reference; ``repro.kernels.swa_attention`` is the Pallas
TPU kernel for the same contraction, and :func:`attention` routes to it via
``repro.kernels.dispatch`` when the call is kernel-eligible (causal
self-attention over the whole sequence — no cache, no offset) and the
``backend`` knob resolves to ``"pallas"``. The kernel route is trained
through a custom VJP over the residual-saving forward
(``swa_attention_fwd_res``) and the fused dq/dk/dv backward
(``swa_attention_bwd``) — no recompute-through-ref pass — with KV handed to
the kernels unexpanded (per-KV-head GQA layout).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _kernel_eligible(causal: bool, q_offset, kv_len, sq: int, sk: int) -> bool:
    """The Pallas kernel covers exactly the training self-attention case:
    causal, full sequence (no KV cache slice, no decode offset)."""
    return (causal and kv_len is None and sq == sk
            and isinstance(q_offset, int) and q_offset == 0)


def _to_kernel_layout(q: jax.Array, k: jax.Array, v: jax.Array):
    """(B, S, H, hd) q + (B, S, KV, hd) k/v -> the kernel's GQA layout:
    q (B*KV, G, S, hd) with query head h = c*G + r grouped under KV head c
    (the `_repeat_kv` convention), k/v (B*KV, S, hd) — UNEXPANDED, so the
    kernel never sees the h/kv-times-inflated KV stream."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    qg = q.transpose(0, 2, 1, 3).reshape(b * kv, h // kv, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    return qg, kf, vf


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _pallas_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      window: int) -> jax.Array:
    """(B, S, H, hd) q, (B, S, KV, hd) k/v -> (B, S, H, hd).

    Forward runs the residual-saving Pallas kernel (out + per-row logsumexp);
    the VJP feeds those residuals to the fused dq/dk/dv kernels via
    ``dispatch.swa_attention_bwd`` — no recompute-through-ref pass. dk/dv are
    accumulated per KV head inside the kernel, so the gradients already carry
    the sum over each query-head group.
    """
    out, _ = _pallas_fwd_res(q, k, v, window)
    return out


def _pallas_fwd_res(q, k, v, window):
    from repro.kernels import dispatch
    b, s, h, hd = q.shape
    qg, kf, vf = _to_kernel_layout(q, k, v)
    out, lse = dispatch.swa_attention_fwd_res(qg, kf, vf, window=window,
                                              backend="pallas")
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3), lse


def _pallas_attention_fwd(q, k, v, window):
    out, lse = _pallas_fwd_res(q, k, v, window)
    return out, (q, k, v, out, lse)


def _pallas_attention_bwd(window, res, g):
    from repro.kernels import dispatch
    q, k, v, out, lse = res
    b, s, h, hd = q.shape
    kv = k.shape[2]
    qg, kf, vf = _to_kernel_layout(q, k, v)
    # o and the cotangent share q's (B, S, H, hd) layout
    og = out.transpose(0, 2, 1, 3).reshape(b * kv, h // kv, s, hd)
    dog = g.transpose(0, 2, 1, 3).reshape(b * kv, h // kv, s, hd)
    dq, dk, dv = dispatch.swa_attention_bwd(qg, kf, vf, og, lse, dog,
                                            window=window, backend="pallas")
    dq = dq.reshape(b, h, s, hd).transpose(0, 2, 1, 3).astype(q.dtype)
    dk = dk.reshape(b, kv, s, hd).transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv.reshape(b, kv, s, hd).transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                            ).reshape(b, s, kv * n_rep, hd)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0, q_offset=0,
              kv_len: Optional[jax.Array] = None,
              chunk: int = 1024,
              backend: Optional[str] = None) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Returns (B, Sq, H, hd).

    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``kv_len``: number of valid cache entries (decode with a fixed-size
    cache); None = all of Sk.
    ``window``: sliding-window size (0 = full); key j is visible to query i
    iff  i - window < j <= i  (Mixtral-style).
    ``backend``: kernel backend knob ("ref" | "pallas" | "auto"); eligible
    calls resolving to "pallas" run the Pallas flash kernel, everything else
    takes the chunked pure-JAX path below.

    Window/offset contract (shared by train, decode, and the ``swa_decode``
    serving kernel; pinned by tests/test_serve_decode.py):

    * ``window == 0`` ALWAYS means full causal — never "window of zero
      keys". A ``window=None`` default exists only at the model layer
      (``DecoderLM._attn`` / ``ServeConfig.window``), where None means
      "inherit the config" and 0 still means full causal.
    * a decode query at ``q_offset == cache_len`` sees exactly
      ``min(cache_len + 1, window)`` keys (its own k/v included) — at the
      boundary ``cache_len + 1 == window`` the whole window is visible and
      the NEXT step is the first to drop a key. In the ring-buffer cache
      (capacity C == window) that first dropped key is the one in slot
      ``(cache_len + 1) % C`` — the slot the next token overwrites, so
      eviction and masking agree by construction
      (``repro.kernels.ref.swa_decode_slot_positions``).
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    if _kernel_eligible(causal, q_offset, kv_len, sq, sk):
        from repro.kernels import dispatch
        # seq-only gate: see dispatch.swa_attention (flash attention is
        # bandwidth-bound; hd=64 heads must not disqualify the kernel)
        if dispatch.resolve(backend, sq) == "pallas":
            # KV stays unexpanded: the kernel layout carries the query-head
            # group explicitly, so bandwidth/memory don't inflate by h/kv
            return _pallas_attention(q, k, v, window)
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    scale = hd ** -0.5
    qf = (q * scale).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, denom, acc = carry
        kj, vj, j0 = xs
        # scores: (B, H, Sq, C)
        s = jnp.einsum("bqhd,bchd->bhqc", qf, kj.astype(jnp.float32))
        k_pos = j0 + jnp.arange(chunk)
        valid = k_pos[None, :] < (kv_len if kv_len is not None else sk)
        if causal:
            vis = k_pos[None, :] <= q_pos[:, None]
            if window:
                vis &= k_pos[None, :] > (q_pos[:, None] - window)
            valid = valid & vis
        s = jnp.where(valid[None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom = denom * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p, vj.astype(jnp.float32))
        return (m_new, denom, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    d0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    j0s = jnp.arange(n_chunks) * chunk
    (m, denom, acc), _ = jax.lax.scan(body, (m0, d0, a0), (kc, vc, j0s))
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention_naive(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_len=None):
    """Reference O(Sq*Sk) materialized-scores attention (oracle for tests)."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    valid = k_pos[None, :] < (kv_len if kv_len is not None else sk)
    if causal:
        vis = k_pos[None, :] <= q_pos[:, None]
        if window:
            vis &= k_pos[None, :] > (q_pos[:, None] - window)
        valid = valid & vis
    s = jnp.where(valid[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
