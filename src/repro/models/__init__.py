from repro.models.transformer import DecoderLM
from repro.models.resnet import ConvNet
