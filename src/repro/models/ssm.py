"""Selective SSM (Mamba-style) branch used by the hymba hybrid blocks.

K-FAC applicability (DESIGN.md §5): the in/out/dt/BC projections are dense
sites; the recurrence parameters (A_log, D, conv kernel, dt bias) are
elementwise/depthwise and have no Kronecker product structure — they take
the first-order fallback, the same decision the paper makes for its
non-factorable parameters (BatchNorm) before inventing unit-wise NGD.

The recurrence is a sequential ``lax.scan`` over time (state carried, O(1)
memory in S — this is what makes the ``long_500k`` decode shape feasible).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tagging
from repro.models.layers import he_normal


def init_ssm(key, d_model: int, state: int, dtype,
             expand: int = 2, dt_rank: Optional[int] = None,
             conv_k: int = 4) -> dict:
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": he_normal(ks[0], (d_model, 2 * d_inner), dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_k, d_inner)) * 0.1
                   ).astype(dtype),
        "xdb": he_normal(ks[2], (d_inner, dt_rank + 2 * state), dtype),
        "dt_proj": he_normal(ks[3], (dt_rank, d_inner), dtype),
        "dt_bias": jnp.zeros((d_inner,), dtype),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, state + 1, dtype=jnp.float32), (d_inner, state))
        ).astype(jnp.float32),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": he_normal(ks[4], (d_inner, d_model), dtype),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array,
                           cache: Optional[jax.Array] = None):
    """x: (B, S, C), w: (K, C). Returns (y, new_cache[(B, K-1, C)])."""
    k = w.shape[0]
    hist = cache if cache is not None else jnp.zeros(
        (x.shape[0], k - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([hist, x], axis=1)
    y = sum(xx[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_cache = xx[:, -(k - 1):, :] if k > 1 else hist
    return y, new_cache


def _ssm_params(x_in, p, fs, spec, state):
    """Shared projections: returns (x_conv_in, z, dt, B, C)."""
    g = lambda n: (fs.get(n) if fs else None)
    xz = tagging.dense_site(x_in, p["in_proj"], g("in_proj"), spec)
    x, z = jnp.split(xz, 2, axis=-1)
    return x, z


def _dt_bc(x, p, fs, spec, state):
    spec_xdb, spec_dt = spec if isinstance(spec, tuple) else (spec, spec)
    g = lambda n: (fs.get(n) if fs else None)
    dt_rank = p["dt_proj"].shape[0]
    xdb = tagging.dense_site(x, p["xdb"], g("xdb"), spec_xdb)
    dt_low = xdb[..., :dt_rank]
    bmat = xdb[..., dt_rank:dt_rank + state]
    cmat = xdb[..., dt_rank + state:]
    dt = tagging.dense_site(dt_low, p["dt_proj"], g("dt_proj"), spec_dt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32)


def ssm_branch(x_seq: jax.Array, p: dict, fs: Optional[dict], *,
               state: int, spec=None, specs: Optional[dict] = None,
               init_state: Optional[jax.Array] = None,
               conv_cache: Optional[jax.Array] = None,
               chunk: int = 0,
               return_state: bool = False):
    """x_seq: (B, S, d_model) -> (B, S, d_model) [+ (ssm_state, conv_cache)].

    ``init_state``: (B, d_inner, state) carried SSM state (decode).
    """
    spec = spec or tagging.FactorSpec()
    sp = lambda n: ((specs or {}).get(n) or spec)
    b, s, d = x_seq.shape
    x, z = _ssm_params(x_seq, p, fs, sp("in_proj"), state)
    x, new_conv = _causal_depthwise_conv(x, p["conv_w"], conv_cache)
    x = jax.nn.silu(x)
    dt, bmat, cmat = _dt_bc(x, p, fs, (sp("xdb"), sp("dt_proj")), state)      # (B,S,di),(B,S,N),(B,S,N)
    a = -jnp.exp(p["a_log"])                            # (di, N)
    xf = x.astype(jnp.float32)

    h0 = init_state if init_state is not None else jnp.zeros(
        (b, x.shape[-1], state), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                           # (B,di),(B,di),(B,N),(B,N)
        da = jnp.exp(dtt[..., None] * a)                # (B, di, N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        yt = jnp.einsum("bdn,bn->bd", h, ct)
        return h, yt

    if chunk and chunk > 1 and s % chunk == 0 and s > chunk:
        # chunk-unrolled scan: the (B, di, N) state stays on-chip for
        # ``chunk`` tokens instead of round-tripping HBM per token
        n = s // chunk

        @jax.checkpoint                                 # recompute in-chunk
        def outer(h, inp):                              # states in backward
            xc, dc, bc, cc = inp                        # (B, chunk, ...)
            outs = []
            for i in range(chunk):
                h, yt = step(h, (xc[:, i], dc[:, i], bc[:, i], cc[:, i]))
                outs.append(yt)
            return h, jnp.stack(outs, axis=1)

        xs = tuple(v.reshape((b, n, chunk) + v.shape[2:]).swapaxes(0, 1)
                   for v in (xf, dt, bmat, cmat))
        h_final, ys = jax.lax.scan(outer, h0, xs)
        ys = ys.swapaxes(0, 1).reshape(b, s, -1)
    else:
        xs = (xf.swapaxes(0, 1), dt.swapaxes(0, 1),
              bmat.swapaxes(0, 1), cmat.swapaxes(0, 1))
        h_final, ys = jax.lax.scan(step, h0, xs)
        ys = ys.swapaxes(0, 1)
    y = ys + xf * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_seq.dtype)
    g = lambda n: (fs.get(n) if fs else None)
    out = tagging.dense_site(y, p["out_proj"], g("out_proj"), sp("out_proj"))
    if return_state:
        return out, (h_final, new_conv)
    return out
