"""RWKV-6 ("Finch") blocks: attention-free time-mix with data-dependent
per-channel decay + squared-ReLU channel-mix. [arXiv:2404.05892]

K-FAC coverage: the r/k/v/g/o and channel-mix matmuls are dense sites; the
token-shift interpolation vectors (mu_*) are scale-like elementwise
parameters tagged unit-wise (1x1); decay base w0 and bonus u take the
first-order fallback (DESIGN.md §5).

State per layer: (last_x_tm, last_x_cm, wkv_state (B, H, hd, hd)) — O(1) in
sequence length, so the long_500k decode shape runs natively.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tagging
from repro.models.layers import he_normal


def init_rwkv_tm(key, d: int, head_dim: int, dtype, lora_r: int = 32) -> dict:
    ks = jax.random.split(key, 9)
    h = d // head_dim
    return {
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "wr": he_normal(ks[0], (d, d), dtype), "wk": he_normal(ks[1], (d, d), dtype),
        "wv": he_normal(ks[2], (d, d), dtype), "wg": he_normal(ks[3], (d, d), dtype),
        "wo": he_normal(ks[4], (d, d), dtype),
        "w0": jnp.zeros((d,), jnp.float32),
        "w_lora_a": he_normal(ks[5], (d, lora_r), dtype),
        "w_lora_b": (jax.random.normal(ks[6], (lora_r, d)) * 0.01).astype(dtype),
        "u_bonus": jnp.zeros((h, head_dim), jnp.float32),
        "ln_scale": jnp.ones((d,), jnp.float32),
    }


def init_rwkv_cm(key, d: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype), "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": he_normal(ks[0], (d, d_ff), dtype),
        "wv": he_normal(ks[1], (d_ff, d), dtype),
        "wr": he_normal(ks[2], (d, d), dtype),
    }


def _token_shift(x: jax.Array, last: Optional[jax.Array]):
    """x: (B, S, d). Returns (x_prev, new_last)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    prev = jnp.concatenate([last, x[:, :-1]], axis=1)
    return prev, x[:, -1:]


def _lerp(x, prev, mu, fs_key, fs):
    """RWKV token-shift interpolation x + (prev - x) * mu, mu tagged 1x1."""
    delta = prev - x
    scaled = tagging.scale_bias_site(delta, mu, None,
                                     fs.get(fs_key) if fs else None)
    return x + scaled


def _wkv_step(st, rt, kt, vt, wt, u):
    """One WKV-6 recurrence step. st: (B, h, hd, hd); others (B, h, hd)."""
    kv = kt[..., :, None] * vt[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", rt, st + u[..., None] * kv)
    st = wt[..., None] * st + kv
    return st, out


def _wkv_scan(rh, kh, vh, wh, u, st0, *, chunk: int = 0):
    """WKV recurrence over (B, S, h, hd) inputs.

    ``chunk > 1``: scan over S/chunk super-steps with the inner ``chunk``
    iterations unrolled — the (B, h, hd, hd) state and the per-token kv outer
    products then live in VMEM/registers inside one fused loop body instead
    of round-tripping HBM every token (TPU adaptation; EXPERIMENTS.md §Perf
    rwkv iteration). Numerically identical to the per-token scan.
    """
    b, s, h, hd = rh.shape
    if chunk and chunk > 1 and s % chunk == 0 and s > chunk:
        n = s // chunk
        xs = tuple(a.reshape(b, n, chunk, h, hd).swapaxes(0, 1)
                   for a in (rh, kh, vh, wh))

        @jax.checkpoint                           # recompute in-chunk states
        def outer(st, inp):                       # in bwd: O(S/chunk) state
            rc, kc, vc, wc = inp                  # (B, chunk, h, hd) memory
            outs = []
            for i in range(chunk):                # unrolled on purpose
                st, out = _wkv_step(st, rc[:, i], kc[:, i], vc[:, i],
                                    wc[:, i], u)
                outs.append(out)
            return st, jnp.stack(outs, axis=1)

        st_final, ys = jax.lax.scan(outer, st0, xs)
        return st_final, ys.swapaxes(0, 1).reshape(b, s, h, hd)

    def step(st, inp):
        rt, kt, vt, wt = inp
        return _wkv_step(st, rt, kt, vt, wt, u)

    xs = tuple(a.swapaxes(0, 1) for a in (rh, kh, vh, wh))
    st_final, outs = jax.lax.scan(step, st0, xs)
    return st_final, outs.swapaxes(0, 1)


def time_mix(x: jax.Array, p: dict, fs: Optional[dict], *, head_dim: int,
             spec=None, specs: Optional[dict] = None,
             last_x: Optional[jax.Array] = None,
             wkv_state: Optional[jax.Array] = None,
             chunk: int = 0,
             return_state: bool = False):
    """RWKV-6 time mixing. x: (B, S, d)."""
    spec = spec or tagging.FactorSpec()
    sp = lambda n: ((specs or {}).get(n) or spec)
    b, s, d = x.shape
    h = d // head_dim
    g = lambda n: (fs.get(n) if fs else None)
    prev, new_last = _token_shift(x, last_x)

    xr = _lerp(x, prev, p["mu_r"], "mu_r", fs)
    xk = _lerp(x, prev, p["mu_k"], "mu_k", fs)
    xv = _lerp(x, prev, p["mu_v"], "mu_v", fs)
    xw = _lerp(x, prev, p["mu_w"], "mu_w", fs)
    xg = _lerp(x, prev, p["mu_g"], "mu_g", fs)

    r = tagging.dense_site(xr, p["wr"], g("wr"), sp("wr"))
    k = tagging.dense_site(xk, p["wk"], g("wk"), sp("wk"))
    v = tagging.dense_site(xv, p["wv"], g("wv"), sp("wv"))
    gate = jax.nn.silu(tagging.dense_site(xg, p["wg"], g("wg"), sp("wg")))

    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(xw)))
    lora = tagging.dense_site(jnp.tanh(
        tagging.dense_site(xw, p["w_lora_a"], g("w_lora_a"), sp("w_lora_a"))),
        p["w_lora_b"], g("w_lora_b"), sp("w_lora_b"))
    logw = p["w0"] + lora.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                          # (B, S, d) in (0,1)

    rh = r.reshape(b, s, h, head_dim).astype(jnp.float32)
    kh = k.reshape(b, s, h, head_dim).astype(jnp.float32)
    vh = v.reshape(b, s, h, head_dim).astype(jnp.float32)
    wh = w.reshape(b, s, h, head_dim)
    u = p["u_bonus"]                                     # (h, hd)

    st0 = wkv_state if wkv_state is not None else jnp.zeros(
        (b, h, head_dim, head_dim), jnp.float32)

    st_final, y = _wkv_scan(rh, kh, vh, wh, u, st0, chunk=chunk)
    y = y.reshape(b, s, d)

    # per-head group norm, scale tagged unit-wise
    yh = y.reshape(b, s, h, head_dim)
    mu_ = yh.mean(-1, keepdims=True)
    var = ((yh - mu_) ** 2).mean(-1, keepdims=True)
    yh = (yh - mu_) * jax.lax.rsqrt(var + 1e-5)
    y = tagging.scale_bias_site(yh.reshape(b, s, d).astype(x.dtype),
                                p["ln_scale"].astype(x.dtype), None,
                                g("ln_scale"))
    y = y * gate.astype(y.dtype)
    out = tagging.dense_site(y, p["wo"], g("wo"), sp("wo"))
    if return_state:
        return out, (new_last, st_final)
    return out


def channel_mix(x: jax.Array, p: dict, fs: Optional[dict], *, spec=None,
                specs: Optional[dict] = None,
                last_x: Optional[jax.Array] = None,
                return_state: bool = False):
    spec = spec or tagging.FactorSpec()
    sp = lambda n: ((specs or {}).get(n) or spec)
    g = lambda n: (fs.get(n) if fs else None)
    prev, new_last = _token_shift(x, last_x)
    xk = _lerp(x, prev, p["mu_k"], "cm_mu_k", fs)
    xr = _lerp(x, prev, p["mu_r"], "cm_mu_r", fs)
    k = tagging.dense_site(xk, p["wk"], g("wk"), sp("wk"))
    k = jnp.square(jax.nn.relu(k))
    kv = tagging.dense_site(k, p["wv"], g("wv"), sp("wv"))
    r = jax.nn.sigmoid(tagging.dense_site(xr, p["wr"], g("wr"), sp("wr")))
    out = r * kv
    if return_state:
        return out, new_last
    return out
