"""Feed-forward blocks (gated SiLU / plain GELU / squared-ReLU)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tagging
from repro.models.layers import activation


def mlp(x: jax.Array, p: dict, fs: Optional[dict], *, act: str = "silu",
        gated: bool = True, spec=None, specs: Optional[dict] = None
        ) -> jax.Array:
    """fs keys (when tagging): "up", "gate", "down"."""
    g = lambda name: (fs.get(name) if fs else None)
    sp = lambda name: ((specs or {}).get(name) or spec
                       or tagging.FactorSpec())
    f = activation(act)
    up = tagging.dense_site(x, p["up"], g("up"), sp("up"))
    if gated:
        gate = tagging.dense_site(x, p["gate"], g("gate"), sp("gate"))
        h = f(gate) * up
    else:
        h = f(up)
    return tagging.dense_site(h, p["down"], g("down"), sp("down"))


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    from repro.models.layers import he_normal
    ks = jax.random.split(key, 3)
    p = {"up": he_normal(ks[0], (d_model, d_ff), dtype),
         "down": he_normal(ks[1], (d_ff, d_model), dtype)}
    if gated:
        p["gate"] = he_normal(ks[2], (d_model, d_ff), dtype)
    return p
