"""Mixture-of-Experts block with capacity-based dispatch and per-expert
Kronecker factors.

The paper's technique extends to MoE as per DESIGN.md §5: every expert's
matmuls are `grouped_dense_site`s whose factor arrays carry the expert axis,
so the distributed schedule reduce-scatters (L, E, nb, b, b) factor families
and each device inverts the expert-blocks it owns. The router is a plain
dense site. Near-empty experts produce near-zero factors; the Tikhonov
damping floor keeps their inverses bounded (noted in DESIGN.md).

Dispatch is the standard top-k + capacity scheme (tokens above capacity are
dropped; the residual path carries them unchanged), implemented with scatter/
gather so it shards cleanly over the data axis under pjit.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tagging
from repro.models.layers import activation, he_normal


def router_probs(x2d, w_router, fs, n_experts: int, top_k: int, spec):
    """Returns (topk_probs (T, k), topk_idx (T, k), aux_loss scalar)."""
    logits = tagging.dense_site(x2d, w_router, fs, spec).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_probs, topk_idx = jax.lax.top_k(probs, top_k)
    topk_probs = topk_probs / jnp.maximum(topk_probs.sum(-1, keepdims=True),
                                          1e-9)
    # Switch-style load-balance auxiliary loss
    me = probs.mean(0)                                   # mean router prob
    one_hot = jax.nn.one_hot(topk_idx[:, 0], n_experts)  # top-1 assignment
    ce = one_hot.mean(0)                                 # fraction routed
    aux = n_experts * jnp.sum(me * ce)
    return topk_probs, topk_idx, aux


def dispatch_combine(x2d, topk_probs, topk_idx, n_experts: int,
                     capacity: int, expert_fn, buf_hook=None):
    """Scatter tokens to (E, C, d), run expert_fn, gather back weighted.

    ``buf_hook`` (optional): sharding-constraint callback applied to the
    dispatch buffer — pins (E, C, d) to the TP layout so the scatter/gather
    stay shard-local (EXPERIMENTS.md §Perf mixtral iteration 2)."""
    t, d = x2d.shape
    k = topk_idx.shape[1]
    # position of each (token, k) assignment within its expert's buffer
    flat_idx = topk_idx.reshape(-1)                      # (T*k,)
    one_hot = jax.nn.one_hot(flat_idx, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(one_hot, axis=0) * one_hot - 1      # (T*k, E)
    pos_in_e = pos.max(-1)                               # (T*k,)
    keep = pos_in_e < capacity
    safe_pos = jnp.where(keep, pos_in_e, capacity - 1)

    buf = jnp.zeros((n_experts, capacity, d), x2d.dtype)
    xk = jnp.repeat(x2d, k, axis=0)                      # token order: t0k0 t0k1 ...
    buf = buf.at[flat_idx, safe_pos].add(
        jnp.where(keep[:, None], xk, 0).astype(x2d.dtype))
    if buf_hook is not None:
        buf = buf_hook(buf)

    out_e = expert_fn(buf)                               # (E, C, d_out)

    gathered = out_e[flat_idx, safe_pos]                 # (T*k, d_out)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = topk_probs.reshape(-1)[:, None].astype(gathered.dtype)
    combined = (gathered * w).reshape(t, k, -1).sum(1)
    return combined


def moe_block(x: jax.Array, p: dict, fs: Optional[dict], *,
              n_experts: int, top_k: int, act: str = "silu",
              capacity_factor: float = 1.25, spec=None,
              specs: Optional[dict] = None, buf_hook=None,
              shared_act: str = "silu") -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (y, aux_loss). Param keys:
    router (d, E); we_up/we_gate/we_down (E, d, f)/(E, f, d);
    optional shared: sh_up, sh_gate, sh_down."""
    b, s, d = x.shape
    spec = spec or tagging.FactorSpec()
    sp = lambda name: ((specs or {}).get(name) or spec)
    g = lambda name: (fs.get(name) if fs else None)
    x2d = x.reshape(-1, d)
    t = x2d.shape[0]
    capacity = max(1, int(capacity_factor * t * top_k / n_experts))

    probs, idx, aux = router_probs(x2d, p["router"], g("router"),
                                   n_experts, top_k, sp("router"))
    f = activation(act)

    def experts(buf):                                    # (E, C, d)
        up = tagging.grouped_dense_site(buf, p["we_up"], g("we_up"),
                                        sp("we_up"))
        gate = tagging.grouped_dense_site(buf, p["we_gate"], g("we_gate"),
                                          sp("we_gate"))
        h = f(gate) * up
        return tagging.grouped_dense_site(h, p["we_down"], g("we_down"),
                                          sp("we_down"))

    y = dispatch_combine(x2d, probs, idx, n_experts, capacity, experts,
                         buf_hook=buf_hook)

    if "sh_up" in p:                                     # always-on shared experts
        from repro.models.mlp import mlp
        y = y + mlp(x2d, {"up": p["sh_up"], "gate": p["sh_gate"],
                          "down": p["sh_down"]},
                    {"up": g("sh_up"), "gate": g("sh_gate"),
                     "down": g("sh_down")} if fs else None,
                    act=shared_act, gated=True, spec=spec,
                    specs={"up": sp("sh_up"), "gate": sp("sh_gate"),
                           "down": sp("sh_down")})
    return y.reshape(b, s, d), aux


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             n_shared: int, dtype) -> dict:
    ks = jax.random.split(key, 7)
    p = {"router": he_normal(ks[0], (d_model, n_experts), dtype),
         "we_up": he_normal(ks[1], (n_experts, d_model, d_ff), dtype),
         "we_gate": he_normal(ks[2], (n_experts, d_model, d_ff), dtype),
         "we_down": he_normal(ks[3], (n_experts, d_ff, d_model), dtype)}
    if n_shared:
        sf = n_shared * d_ff
        p["sh_up"] = he_normal(ks[4], (d_model, sf), dtype)
        p["sh_gate"] = he_normal(ks[5], (d_model, sf), dtype)
        p["sh_down"] = he_normal(ks[6], (sf, d_model), dtype)
    return p
