"""The paper's own benchmark model family (scaled): conv + BatchNorm net
exercising conv K-FAC (Eq. 10-11) and unit-wise BN Fisher (Eq. 15-17)."""
from repro.models.resnet import ConvNetConfig

CONFIG = ConvNetConfig(n_classes=10, widths=(16, 32, 64), blocks_per_stage=2)
