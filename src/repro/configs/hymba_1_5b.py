"""Hymba-1.5B hybrid: 32L, d=1600, 25 heads (GQA kv=5), d_ff=5504,
vocab=32001, parallel attention + mamba heads, ssm_state=16.
[arXiv:2411.13676]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba_1_5b", arch_type="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001, head_dim=64,
    block_type="hymba", act="silu", gated_mlp=True,
    ssm_state=16, ssm_expand=2, norm="rmsnorm",
    source="arXiv:2411.13676",
)
