"""Nemotron-4-340B dense decoder: 96L, d=18432, 96 heads (GQA kv=8),
d_ff=73728, vocab=256000, squared-ReLU MLP (ungated). [arXiv:2402.16819]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron_4_340b", arch_type="dense", n_layers=96, d_model=18432,
    n_heads=96, n_kv_heads=8, d_ff=73728, vocab=256000, head_dim=192,
    block_type="dense", act="relu2", gated_mlp=False, rope_theta=1e4,
    norm="layernorm", kfac_max_dim=4096,
    source="arXiv:2402.16819",
)
