"""RWKV-6 "Finch" 7B: 32L, d=4096, attention-free (64 wkv heads of 64),
d_ff=14336, vocab=65536, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6_7b", arch_type="ssm", n_layers=32, d_model=4096,
    n_heads=64, n_kv_heads=64, d_ff=14336, vocab=65536, head_dim=64,
    block_type="rwkv", norm="layernorm",
    source="arXiv:2404.05892",
)
