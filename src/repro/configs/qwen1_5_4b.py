"""Qwen1.5-4B-class dense decoder: 40L, d=2560, 20 heads (MHA: kv=20),
d_ff=6912, vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-0.5B family]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1_5_4b", arch_type="dense", n_layers=40, d_model=2560,
    n_heads=20, n_kv_heads=20, d_ff=6912, vocab=151936, head_dim=128,
    block_type="dense", act="silu", gated_mlp=True, qkv_bias=True,
    rope_theta=1e6, norm="rmsnorm",
    source="hf:Qwen/Qwen1.5-0.5B",
)
