"""Llama-3.2-3B dense decoder: 28L, d=3072, 24 heads (GQA kv=8), d_ff=8192,
vocab=128256. [hf:meta-llama/Llama-3.2-1B family]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3_2_3b", arch_type="dense", n_layers=28, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=8192, vocab=128256, head_dim=128,
    block_type="dense", act="silu", gated_mlp=True, rope_theta=5e5,
    norm="rmsnorm",
    source="hf:meta-llama/Llama-3.2-1B",
)
