"""Mixtral-8x22B MoE: 56L, d=6144, 48 heads (GQA kv=8), expert d_ff=16384,
vocab=32768, 8 experts top-2, sliding-window attention. [arXiv:2401.04088]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral_8x22b", arch_type="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768, head_dim=128,
    block_type="moe", act="silu", gated_mlp=True,
    n_experts=8, top_k=2, sliding_window=4096, rope_theta=1e6,
    norm="rmsnorm", kfac_max_dim=4096,
    source="arXiv:2401.04088",
)
