"""LLaVA-NeXT-34B VLM backbone: 60L, d=7168, 56 heads (GQA kv=8),
d_ff=20480, vocab=64000. AnyRes tiling: the ViT/SigLIP vision tower +
anyres tiler is the stubbed frontend — input_specs supplies precomputed
patch embeddings (2880 tokens = 5 tiles x 576 patches, dim 1152) which the
in-model projector maps to d_model. [hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava_next_34b", arch_type="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000, head_dim=128,
    block_type="dense", act="silu", gated_mlp=True, rope_theta=5e6,
    norm="rmsnorm", kfac_max_dim=4096,
    frontend="vision", frontend_tokens=2880, frontend_dim=1152,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
