"""Llama-3.2-1B dense decoder: 16L, d=2048, 32 heads (GQA kv=8), d_ff=8192,
vocab=128256. [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3_2_1b", arch_type="dense", n_layers=16, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=128256, head_dim=64,
    block_type="dense", act="silu", gated_mlp=True, rope_theta=5e5,
    norm="rmsnorm",
    source="hf:meta-llama/Llama-3.2-1B",
)
