"""Qwen2-MoE-A2.7B: 24L, d=2048, 16 heads (MHA kv=16), expert d_ff=1408,
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_moe_a2_7b", arch_type="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=151936, head_dim=128,
    block_type="moe", act="silu", gated_mlp=True,
    n_experts=60, top_k=4, n_shared_experts=4, rope_theta=1e6,
    norm="rmsnorm",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
