"""MusicGen-medium audio decoder backbone: 48L, d=1536, 24 heads (MHA),
d_ff=6144, vocab=2048 (EnCodec codebook). Decoder-only over EnCodec tokens;
the EnCodec tokenizer itself is the stubbed frontend — input_specs feeds
token ids directly (the codebook-delay interleave is upstream of the
backbone). GELU, LayerNorm. [arXiv:2306.05284]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen_medium", arch_type="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048, head_dim=64,
    block_type="dense", act="gelu", gated_mlp=False, norm="layernorm",
    frontend="audio",
    source="arXiv:2306.05284",
)
