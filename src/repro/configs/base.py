"""Architecture configuration schema + registry (--arch <id>) and the four
assigned input shapes."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    block_type: str = "dense"    # dense | moe | hymba | rwkv
    act: str = "silu"
    gated_mlp: bool = True
    qkv_bias: bool = False
    rope_theta: float = 5e5
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    # attention
    sliding_window: int = 0      # 0 = full causal
    # frontend stubs (vlm / audio)
    frontend: str = "none"       # none | vision | audio
    frontend_tokens: int = 0     # patches / frames prepended
    frontend_dim: int = 0        # raw embedding dim before projector
    # kernels
    backend: str = "auto"        # "ref" | "pallas" | "auto" (kernels.dispatch)
    # K-FAC
    kfac_max_dim: int = 2048
    factor_wire: str = ""        # "" = dense f32 factor capture; "e4m3" /
                                 # "e5m2" = the fused SYRK epilogue emits
                                 # wire-format (fp8 payload + per-block
                                 # scale) sums for full-kind factors
    head_g_kind: str = "diag"    # vocab-side factor of the LM head
    tp_shards: int = 0           # >0: align factor blocks to TP shard width
    min_block: int = 128         # don't align below this block size (MXU)
    scan_chunk: int = 0          # >0: chunk recurrent scans (rwkv/ssm state
                                 # stays on-chip for `scan_chunk` tokens)
    # numerics / memory
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # citation
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def validate(self) -> None:
        if self.block_type in ("dense", "moe", "hymba"):
            assert self.n_heads > 0 and self.n_heads % self.n_kv_heads == 0
        if self.block_type == "moe":
            assert self.n_experts > 0 and self.top_k > 0

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims (2 layers, d<=512,
        <=4 experts)."""
        hd = min(self.hd, 64)
        n_heads = max(2, min(4, self.n_heads)) if self.n_heads else 0
        n_kv = max(1, min(n_heads, max(1, self.n_kv_heads * n_heads
                                       // max(self.n_heads, 1))))
        kw = dict(
            n_layers=2,
            d_model=min(self.d_model, hd * max(n_heads, 2) if n_heads else 128),
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 256),
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            kfac_max_dim=128,
            dtype=jnp.float32,
            remat=False,
        )
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCHS = [
    "qwen1_5_4b", "hymba_1_5b", "musicgen_medium", "llama3_2_1b",
    "mixtral_8x22b", "qwen2_moe_a2_7b", "llava_next_34b", "nemotron_4_340b",
    "rwkv6_7b", "llama3_2_3b", "resnet50",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({"qwen1.5-4b": "qwen1_5_4b", "hymba-1.5b": "hymba_1_5b",
                 "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
                 "llama3.2-1b": "llama3_2_1b", "llama3.2-3b": "llama3_2_3b"})


def list_archs() -> list[str]:
    return list(ARCHS)


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.CONFIG
    if isinstance(cfg, ArchConfig):
        cfg.validate()
    return cfg
