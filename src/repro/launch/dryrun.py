import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_BASE_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) pair, lower + compile the appropriate
step (train / prefill / single-token decode) against the production mesh —
16x16 single pod and 2x16x16 multi-pod — using ShapeDtypeStruct stand-ins
(no allocation), and record:

  * compiled.memory_analysis()  (bytes per device: does it fit)
  * compiled.cost_analysis()    (FLOPs / bytes for the roofline)
  * collective bytes parsed from the optimized HLO (roofline 3rd term)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs, INPUT_SHAPES
from repro.configs.base import ArchConfig, InputShape
from repro.core.ngd import NGDConfig, SPNGD
from repro.launch import compat
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (analyze_hlo, roofline_terms,
                                   model_flops_train, model_flops_decode)
from repro.launch.train import (make_train_step, make_serve_step,
                                make_prefill_step, make_shardmap_train_step,
                                make_shardmap_fast_step, make_fast_step)
from repro.models.transformer import DecoderLM

LM_ARCHS = [a for a in list_archs() if a != "resnet50"]

# dense/MoE full-attention archs run long_500k with a sliding-window variant
SWA_FOR_LONG = 8192


def effective_config(arch: str, shape_name: str) -> Optional[ArchConfig]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k":
        if cfg.block_type in ("rwkv",):
            return cfg                     # O(1)-state: native
        if cfg.block_type == "hymba":
            # hybrid: SSM branch is O(1); attention branch gets a window
            return dataclasses.replace(cfg, sliding_window=SWA_FOR_LONG)
        if cfg.sliding_window == 0:
            # dense/moe full attention: run the documented SWA variant
            return dataclasses.replace(cfg, sliding_window=SWA_FOR_LONG)
    return cfg


def pick_accum(cfg: ArchConfig, shape: InputShape, data_shards: int) -> int:
    if shape.kind != "train":
        return 1
    per_shard = 1 if cfg.d_model >= 6144 else 4
    return max(1, shape.global_batch // (per_shard * data_shards))


def count_params(shapes) -> int:
    import math
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def active_param_fraction(cfg: ArchConfig) -> float:
    """Fraction of expert params active per token (MoE 6*N_active*D)."""
    if cfg.n_experts:
        # router dispatch: top_k of n_experts routed + shared always on
        return (cfg.top_k + cfg.n_shared_experts) / (
            cfg.n_experts + cfg.n_shared_experts)
    return 1.0


def build_case(arch: str, shape_name: str, mesh, *,
               schedule: str = "auto", tp_align: bool = False,
               rwkv_chunk: int = 0, fast: bool = False,
               backend: str = "auto", factor_dtype: str = "f32",
               inverse_method: str = "eigh", comm_strategy: str = "dense",
               wire_dtype: Optional[str] = None,
               devices_per_host: Optional[int] = None,
               inverse_sharding: bool = False,
               refresh_chunks: int = 1):
    """Returns (step_fn, example_args, n_params, label).

    schedule: "auto" (GSPMD everything — baseline) | "shardmap" (the paper's
    explicit 5-stage Algorithm 3). tp_align: factor blocks aligned to TP
    shard boundaries (beyond-paper, DESIGN.md §4). backend: kernel backend
    for the hot paths (repro.kernels.dispatch) — threaded through both the
    jit and shard_map schedules via the arch config and NGDConfig.
    factor_dtype: factor-history storage ("f32" | "bf16" | "fp8_e4m3" |
    "fp8_e5m2"; fp8 stores sym-packed payloads + per-block scales, so the
    dry-run's memory_analysis sees the compressed optimizer state).
    inverse_method: Stage-4 inversion ("eigh" | "cholesky" |
    "newton_schulz" — the matmul-only iteration the dry-run's cost_analysis
    then counts as GEMM FLOPs instead of an opaque eigendecomposition).
    comm_strategy/wire_dtype: Stage-3 factor reduce under the shardmap
    schedule (repro.comm) — the ring strategies swap the psum_scatter for
    ppermute hops, visible in the dry-run's collective-permute byte
    column. inverse_sharding: Stage-4 distribution (repro.comm.Stage4
    Inverter) — each device inverts only its reducer-owned factor chunk and
    the preconditioners all-gather (implies the double buffer), so the
    dry-run compiles the sharded refresh at production mesh scale.
    refresh_chunks: chunked refresh pipeline (repro.core.pipeline) — K>1
    compiles the capture step (no inline inversions; Stage-4 drains over
    the next K fast steps), so the dry-run's cost/memory analysis shows
    the overlapped step programs. Implies the double buffer."""
    cfg = effective_config(arch, shape_name)
    if backend != "auto":
        cfg = dataclasses.replace(cfg, backend=backend)
    if tp_align:
        cfg = dataclasses.replace(cfg, tp_shards=mesh.shape["model"])
    if rwkv_chunk:
        cfg = dataclasses.replace(cfg, scan_chunk=rwkv_chunk)
    shape = INPUT_SHAPES[shape_name]
    comm = None
    if schedule == "shardmap" and shape.kind == "train":
        from repro.comm import make_comm_config
        comm = make_comm_config(comm_strategy, wire_dtype,
                                backend=cfg.backend,
                                devices_per_host=devices_per_host)
        if comm.strategy == "fused" and not fast:
            # fused: the SYRK epilogue itself emits wire-format payloads —
            # thread the fp8 wire format into the capture specs so the
            # model's factor sums come out pre-packed
            cfg = dataclasses.replace(cfg, factor_wire=comm.wire_fmt or "")
    model = DecoderLM(cfg)
    dp = shd.dp_axes(mesh)
    data_shards = 1
    for a in dp:
        data_shards *= mesh.shape[a]

    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    # the paper's pure-DP schedule replicates weights (no TP) — use it for
    # archs that fit per device; keep GSPMD TP for the big ones
    sm_manual = "all" if cfg.d_model < 6144 else "dp"
    if schedule == "shardmap" and sm_manual == "all" and shape.kind == "train":
        p_specs = jax.tree.map(lambda _: P(), params_shape)
    else:
        p_specs = shd.params_pspecs(params_shape, cfg, mesh=mesh)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    params_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params_shape, p_sh)
    n_params = count_params(params_shape)

    batch_shape = model.input_specs(shape)

    # sequence-parallel residual constraint. NOT applied under the shardmap
    # schedule: mixing a seq-dim constraint with partial-manual axes trips an
    # XLA SPMD partitioner crash ("Invalid binary instruction opcode copy",
    # cf. the b/433785288 resharding path) on this toolchain.
    if cfg.d_model >= 2048 and schedule != "shardmap":
        def act_hook(h):
            if h.shape[1] >= mesh.shape["model"]:
                return jax.lax.with_sharding_constraint(
                    h, NamedSharding(mesh, P(dp, "model", None)))
            return h
        model.act_hook = act_hook

    # dispatch-buffer constraint is part of the optimized (--tp-align)
    # variant; baselines stay compiler-auto
    if cfg.n_experts and shape.kind == "train" and tp_align:
        def moe_hook(buf):                       # (E, C, d): keep d on TP
            return jax.lax.with_sharding_constraint(
                buf, NamedSharding(mesh, P(None, None, "model")))
        model.moe_hook = moe_hook

    if shape.kind == "train":
        from repro.quant import FACTOR_DTYPES
        opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                    model.site_counts,
                    NGDConfig(backend=cfg.backend,
                              inverse_method=inverse_method,
                              factor_dtype=FACTOR_DTYPES[factor_dtype],
                              inverse_sharding=inverse_sharding,
                              double_buffer=(inverse_sharding
                                             or refresh_chunks > 1),
                              refresh_chunks=refresh_chunks),
                    sharding_hook=shd.factor_sharding_hook(mesh))
        accum = pick_accum(cfg, shape, data_shards)
        if schedule == "shardmap":
            if sm_manual == "all":
                accum = max(1, shape.global_batch
                            // len(mesh.devices.flatten()))
            if cfg.factor_wire:
                accum = 1      # fp8 wire payloads cannot scan-accumulate
            if fast:
                step = make_shardmap_fast_step(model, opt, mesh, accum=accum,
                                               manual_axes=sm_manual,
                                               comm=comm)
            else:
                step = make_shardmap_train_step(model, opt, mesh,
                                                accum=accum,
                                                manual_axes=sm_manual,
                                                comm=comm)
        elif fast:
            step = make_fast_step(model, opt, accum=accum)
        else:
            step = make_train_step(model, opt, accum=accum)
        opt_shape = jax.eval_shape(opt.init, params_sds)
        o_specs = shd.opt_state_pspecs(opt_shape, p_specs, mesh)
        o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s)
                            if isinstance(s, P) else s, o_specs,
                            is_leaf=lambda x: isinstance(x, P))
        opt_sds = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            opt_shape, o_sh)
        b_specs = shd.batch_pspecs(batch_shape, mesh)
        batch_sds = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                              sharding=NamedSharding(mesh, s)),
            batch_shape, b_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        scal = jax.ShapeDtypeStruct((), jnp.float32)
        if fast:
            args = (params_sds, opt_sds, batch_sds, scal, scal, scal)
            return step, args, n_params, f"train-fast(accum={accum},{schedule})"
        flags = {k: jax.ShapeDtypeStruct((), jnp.bool_)
                 for k in opt.stat_names()}
        args = (params_sds, opt_sds, batch_sds, flags, scal, scal, scal)
        return step, args, n_params, f"train(accum={accum},{schedule})"

    if shape.kind == "prefill":
        step = make_prefill_step(model)
        b_specs = shd.batch_pspecs(batch_shape, mesh)
        batch_sds = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                              sharding=NamedSharding(mesh, s)),
            batch_shape, b_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        return step, (params_sds, batch_sds), n_params, "prefill"

    # decode
    step = make_serve_step(model)
    b_specs = shd.batch_pspecs(batch_shape, mesh)
    batch_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        batch_shape, b_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return step, (params_sds, batch_sds["cache"], batch_sds["tokens"]), \
        n_params, "decode"


def run_case(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: Optional[str] = None, schedule: str = "auto",
             tp_align: bool = False, rwkv_chunk: int = 0,
             fast: bool = False, backend: str = "auto",
             factor_dtype: str = "f32",
             inverse_method: str = "eigh", comm_strategy: str = "dense",
             wire_dtype: Optional[str] = None,
             devices_per_host: Optional[int] = None,
             inverse_sharding: bool = False,
             refresh_chunks: int = 1) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.flatten())
    shape = INPUT_SHAPES[shape_name]
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "schedule": schedule,
           "tp_align": tp_align, "backend": backend,
           "factor_dtype": factor_dtype, "inverse_method": inverse_method,
           "comm_strategy": comm_strategy,
           "inverse_sharding": inverse_sharding,
           "refresh_chunks": refresh_chunks,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips}
    try:
        with compat.set_mesh(mesh):
            step, args, n_params, label = build_case(
                arch, shape_name, mesh, schedule=schedule, tp_align=tp_align,
                rwkv_chunk=rwkv_chunk, fast=fast, backend=backend,
                factor_dtype=factor_dtype, inverse_method=inverse_method,
                comm_strategy=comm_strategy, wire_dtype=wire_dtype,
                devices_per_host=devices_per_host,
                inverse_sharding=inverse_sharding,
                refresh_chunks=refresh_chunks)
            reducer = getattr(step, "reducer", None)
            if reducer is not None:
                rec["comm"] = reducer.scatter_report()
                if reducer.template is not None:
                    rec["comm"]["wire_bytes_per_refresh"] = sum(
                        reducer.wire_bytes_per_stat().values())
                    levels = reducer.wire_bytes_per_stat_levels().values()
                    rec["comm"]["wire_intra_bytes_per_refresh"] = sum(
                        intra for intra, _ in levels)
                    rec["comm"]["wire_inter_bytes_per_refresh"] = sum(
                        inter for _, inter in levels)
                    # Stage-4 gather leg: bytes the preconditioner
                    # all-gather moves per refresh (0 when the inversion is
                    # replicated — nothing to gather)
                    rec["comm"]["gather_bytes_per_refresh"] = (
                        sum(reducer.gather_bytes_per_stat().values())
                        if inverse_sharding else 0)
                    rec["stage4"] = stage4_report(
                        reducer, inverse_sharding, inverse_method)
            lowered = jax.jit(step).lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            cost = compat.cost_analysis(compiled)
            hlo = compiled.as_text()
        ana = analyze_hlo(hlo)
        # the compiled module is the per-device SPMD program: scale to global
        flops = float(ana.flops) * n_chips     # trip-weighted (see roofline.py)
        hbm = float(ana.hbm_bytes) * n_chips
        coll_total = float(ana.collective_bytes) * n_chips
        static_flops = float(cost.get("flops", 0.0))
        static_bytes = float(cost.get("bytes accessed", 0.0))
        cfg = effective_config(arch, shape_name)
        frac = active_param_fraction(cfg)
        n_active = n_params * frac if cfg.n_experts == 0 else _active_params(cfg)
        if shape.kind == "train":
            mflops = model_flops_train(n_active, shape.global_batch * shape.seq_len)
        elif shape.kind == "prefill":
            mflops = 2.0 * n_active * shape.global_batch * shape.seq_len
        else:
            mflops = model_flops_decode(n_active, shape.global_batch)
        terms = roofline_terms(flops, hbm, coll_total, n_chips)
        rec.update({
            "label": label, "status": "ok",
            "n_params": int(n_params), "n_params_active": int(n_active),
            "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
            "hlo_flops": flops, "hlo_bytes": hbm,
            "static_flops": static_flops, "static_bytes": static_bytes,
            "collective_bytes": coll_total,
            "collective_by_kind": ana.bytes_by_kind,
            "collective_counts": ana.count_by_kind,
            "model_flops": mflops,
            "useful_flops_ratio": (mflops / flops) if flops else None,
            "memory_analysis": _mem_dict(mem),
            **terms,
        })
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    except Exception as e:
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    return rec


def _active_params(cfg: ArchConfig) -> float:
    """Active params/token for MoE: non-expert params + top_k routed +
    shared experts."""
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    per_expert = 3 * d * ff
    routed_total = cfg.n_experts * per_expert * L
    shared_total = (3 * d * ff * cfg.n_shared_experts) * L
    gated = 3 if cfg.gated_mlp else 2
    attn = L * (2 * d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv_heads * cfg.hd)
    emb = 2 * cfg.vocab * d
    other = attn + emb + L * d * cfg.n_experts  # router
    active = other + shared_total + L * cfg.top_k * per_expert
    return active


def stage4_report(reducer, inverse_sharding: bool, method: str) -> dict:
    """Per-layer Stage-4 inversion timing + gather bytes for the scatter
    report (make_report's §Stage-4 input). For every full-kind factor the
    reducer knows, invert ONE leading slice of a synthetic SPD stand-in
    with the configured method on the dry-run host — the dry run never
    materializes real factors — and scale by the layer count / scatter
    group, so the report can show the modelled replicated-vs-sharded
    refresh cost per layer without running a training step."""
    import math
    import time as _time

    import numpy as np

    from repro.comm.comm import _leaf_shape
    from repro.kernels import dispatch

    gather = reducer.gather_bytes_per_stat()
    rep = {"inverse_sharding": inverse_sharding, "method": method,
           "stats": {}}
    rng = np.random.RandomState(0)
    for fam, stats in reducer.template.items():
        for key, leaf in stats.items():
            if key not in ("a", "g") or not reducer.sym_fn(fam, key):
                continue
            shape = _leaf_shape(leaf)          # (lead..., nb, b, b)
            lead = shape[0]
            axes = reducer.scatter_axes(lead)
            p = reducer.group_size(axes) if axes else 1
            b = shape[-1]
            one = (1,) + tuple(shape[1:])      # one leading (layer) slice
            m = rng.randn(*one[:-1], b).astype(np.float32)
            spd = jnp.asarray(m @ np.swapaxes(m, -1, -2) / b
                              + 0.1 * np.eye(b, dtype=np.float32))
            fn = jax.jit(lambda s: dispatch.damped_inverse(
                s, jnp.asarray(1e-3, jnp.float32), method=method))
            fn(spd).block_until_ready()        # compile + warm
            t0 = _time.perf_counter()
            fn(spd).block_until_ready()
            us = (_time.perf_counter() - t0) * 1e6
            name = f"{fam}.{key}"
            rep["stats"][name] = {
                "block_shape": list(shape),
                "us_per_layer": us,
                "layers": int(lead),
                "group": int(p),
                "replicated_us_per_device": us * lead,
                "sharded_us_per_device": us * math.ceil(lead / p),
                "gather_bytes": int(gather.get(name, 0))
                if inverse_sharding else 0,
            }
    return rep


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--schedule", default="auto", choices=["auto", "shardmap"])
    ap.add_argument("--backend", default="auto",
                    choices=["ref", "pallas", "auto"],
                    help="kernel backend (repro.kernels.dispatch); pallas "
                         "includes the fused attention backward")
    from repro.quant import FACTOR_DTYPES
    ap.add_argument("--factor-dtype", default="f32",
                    choices=sorted(FACTOR_DTYPES),
                    help="factor-history storage dtype (repro.quant); fp8 "
                         "shrinks the optimizer-state arrays the dry-run's "
                         "memory_analysis accounts")
    ap.add_argument("--inverse-method", default="eigh",
                    choices=["eigh", "cholesky", "newton_schulz"],
                    help="Stage-4 factor inversion; newton_schulz is the "
                         "matmul-only blocked iteration (MXU-resident under "
                         "--backend pallas, eigh fallback for blocks that "
                         "fail to contract)")
    from repro.comm import STRATEGIES, WIRE_DTYPES
    ap.add_argument("--comm-strategy", default="dense", choices=STRATEGIES,
                    help="Stage-3 factor reduce under --schedule shardmap "
                         "(repro.comm): dense psum_scatter, ring "
                         "reduce-scatter over sym-packed triangles, "
                         "ring_fp8 fp8-wire hops, hier (two-level "
                         "intra-host/inter-host reduce), or fused "
                         "(pre-packed payloads from the SYRK epilogue)")
    ap.add_argument("--wire-dtype", default=None,
                    choices=sorted(WIRE_DTYPES),
                    help="collective wire dtype; defaults to f32 for "
                         "dense/ring, fp8_e4m3 for ring_fp8/hier/fused")
    ap.add_argument("--devices-per-host", type=int, default=None,
                    help="hier host-topology model: width of the "
                         "full-precision intra-host level (default: "
                         "jax.local_device_count())")
    ap.add_argument("--inverse-sharding", action="store_true",
                    help="Stage-4 distribution (repro.comm.Stage4Inverter): "
                         "each device inverts only its reducer-owned factor "
                         "chunk and preconditioners all-gather; implies the "
                         "double buffer and records per-layer inverse "
                         "timing + gather bytes in the scatter report")
    ap.add_argument("--refresh-chunks", type=int, default=1,
                    help="chunked refresh pipeline (repro.core.pipeline): "
                         "K>1 compiles the capture step (no inline "
                         "inversions; the Stage-4 work drains over the "
                         "next K fast steps) — pair with --fast to see "
                         "the drain-step program. Implies the double "
                         "buffer")
    ap.add_argument("--tp-align", action="store_true")
    ap.add_argument("--rwkv-chunk", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="Algorithm 1 no-refresh steady-state step")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="write one dryrun_case event per record (plus "
                         "per-case spans and the console mirror) to this "
                         "JSONL stream (repro.obs.MetricsLogger)")
    args = ap.parse_args()
    if args.comm_strategy != "dense" and args.schedule != "shardmap":
        # the GSPMD-auto schedule has no explicit Stage-3 collective; a
        # record tagged ring/ring_fp8 that actually measured GSPMD would lie
        ap.error("--comm-strategy requires --schedule shardmap")
    if args.inverse_sharding and args.schedule != "shardmap":
        # the sharded Stage-4 refresh rides the reducer's scatter layout,
        # which only exists under the explicit shardmap schedule
        ap.error("--inverse-sharding requires --schedule shardmap")

    archs = LM_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    variant = ""
    if args.schedule != "auto":
        variant += f"__{args.schedule}"
    if args.backend != "auto":
        variant += f"__{args.backend}"
    if args.factor_dtype != "f32":
        variant += f"__{args.factor_dtype}"
    if args.inverse_method != "eigh":
        variant += f"__{args.inverse_method}"
    if args.comm_strategy != "dense":
        variant += f"__{args.comm_strategy}"
        if args.wire_dtype:
            variant += f"__{args.wire_dtype}"
        if args.devices_per_host:
            variant += f"__dph{args.devices_per_host}"
    if args.inverse_sharding:
        variant += "__invshard"
    if args.refresh_chunks > 1:
        variant += f"__rc{args.refresh_chunks}"
    if args.tp_align:
        variant += "__tpalign"
    if args.rwkv_chunk:
        variant += f"__chunk{args.rwkv_chunk}"
    if args.fast:
        variant += "__fast"
    from repro.obs import MetricsLogger
    log = MetricsLogger(args.metrics_jsonl)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = (f"{arch}__{shape}__{'multi' if mp else 'single'}"
                       f"{variant}")
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    log.console(f"[skip] {tag}")
                    continue
                hlo_path = (os.path.join(args.out, tag + ".hlo.txt")
                            if args.save_hlo else None)
                with log.span(f"dryrun.{tag}"):
                    rec = run_case(arch, shape, mp, save_hlo=hlo_path,
                                   schedule=args.schedule,
                                   tp_align=args.tp_align,
                                   rwkv_chunk=args.rwkv_chunk,
                                   fast=args.fast,
                                   backend=args.backend,
                                   factor_dtype=args.factor_dtype,
                                   inverse_method=args.inverse_method,
                                   comm_strategy=args.comm_strategy,
                                   wire_dtype=args.wire_dtype,
                                   devices_per_host=args.devices_per_host,
                                   inverse_sharding=args.inverse_sharding,
                                   refresh_chunks=max(1, args.refresh_chunks))
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                log.emit("dryrun_case", tag=tag,
                         **{k: v for k, v in rec.items()
                            if k != "traceback"})
                status = rec["status"]
                extra = ("" if status != "ok" else
                         f" flops={rec['hlo_flops']:.3g}"
                         f" coll={rec['collective_bytes']:.3g}B"
                         f" bottleneck={rec['bottleneck']}"
                         f" compile={rec['compile_s']}s")
                log.console(f"[{status}] {tag}{extra}")
                if status != "ok":
                    log.console(rec["error"])
    log.close()


if __name__ == "__main__":
    main()
