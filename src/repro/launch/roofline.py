"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * LINK_BW)

``compiled.cost_analysis()`` counts every while-loop body ONCE — useless for
scan-over-layers models where all the work is inside loops. We therefore
analyze the optimized HLO text ourselves, walking the call graph from the
entry computation and weighting each while body by its trip count (extracted
from the integer constants in the loop condition):

  * FLOPs: dot instructions (2 * numel(result) * contracted-dim product),
    found at top level and inside fusion bodies;
  * HBM bytes: per top-level instruction, parameter + result bytes of
    fusions / dots / collectives / copies (fusion-interior ops don't touch
    HBM);
  * collective bytes: result-shape bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment). Both the trip-weighted numbers and the raw
cost_analysis values are recorded so the correction is visible.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_TYPES = "|".join(DTYPE_BYTES)
_SHAPE_RE = re.compile(rf"\b({_TYPES})\[([\d,]*)\]")
_DEF_RE = re.compile(rf"%?([\w.\-]+)\s*=\s*(\(?)(({_TYPES})\[[\d,]*\])")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(segment: str) -> int:
    return sum(_numel(dims) * DTYPE_BYTES[dt]
               for dt, dims in _SHAPE_RE.findall(segment))


def _first_shape(segment: str):
    m = _SHAPE_RE.search(segment)
    if not m:
        return None
    return m.group(1), [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class HloComputation:
    name: str
    param_shapes: list          # [(dtype, dims), ...]
    lines: list
    defs: dict                  # instr name -> (dtype, dims)


@dataclasses.dataclass
class HloAnalysis:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    bytes_by_kind: dict
    count_by_kind: dict


def _parse_computations(hlo: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, HloComputation] = {}
    cur: Optional[HloComputation] = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.strip()
        m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->", line)
        if m and line.endswith("{"):
            params = []
            for pm in _SHAPE_RE.finditer(m.group(3)):
                params.append((pm.group(1),
                               [int(d) for d in pm.group(2).split(",") if d]))
            cur = HloComputation(m.group(2), params, [], {})
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
        elif line == "}":
            cur = None
        elif cur is not None and "=" in line:
            cur.lines.append(line)
            dm = _DEF_RE.match(line)
            if dm:
                fs = _first_shape(line.split("=", 1)[1])
                if fs:
                    cur.defs[dm.group(1)] = fs
    return comps, entry


def _operand_names(line: str) -> list[str]:
    """Operand instruction names of the op call on this line."""
    m = re.search(r"\w[\w\-]*\(([^)]*)\)", line.split("=", 1)[1])
    if not m:
        return []
    names = re.findall(r"%([\w.\-]+)", m.group(1))
    if not names:  # operands may be bare names without % in some dialects
        names = [t.strip() for t in m.group(1).split(",")
                 if t.strip() and "[" not in t]
    return names


def _dot_flops(line: str, comp: HloComputation) -> float:
    """2 * numel(result) * contracted size for a dot instruction."""
    out = _first_shape(line.split("=", 1)[1])
    if out is None:
        return 0.0
    _, out_dims = out
    ops = _operand_names(line)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contracted = 1
    if cm and ops:
        lhs_shape = comp.defs.get(ops[0])
        if lhs_shape is None and ops[0].startswith("param"):
            lhs_shape = None
        if lhs_shape:
            for ci in cm.group(1).split(","):
                if ci:
                    idx = int(ci)
                    if idx < len(lhs_shape[1]):
                        contracted *= lhs_shape[1][idx]
    # operand shapes may be printed inline:
    if contracted == 1 and cm:
        inline = _SHAPE_RE.findall(line.split("=", 1)[1])
        if len(inline) >= 2:
            lhs_dims = [int(d) for d in inline[1][1].split(",") if d]
            for ci in cm.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    contracted *= lhs_dims[int(ci)]
    return 2.0 * _numel(",".join(map(str, out_dims))) * contracted


def _trip_count(comp: Optional[HloComputation]) -> int:
    if comp is None:
        return 1
    best = 1
    for ln in comp.lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


_CALLED_RE = re.compile(
    r"(?:calls=|body=|to_apply=)%?([\w.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def analyze_hlo(hlo: str) -> HloAnalysis:
    comps, entry = _parse_computations(hlo)
    if entry is None:
        for name in comps:
            if name.startswith("main"):
                entry = name
                break
        else:
            entry = next(iter(comps), None)

    flops = 0.0
    hbm = 0.0
    coll_bytes = {k: 0.0 for k in _COLL_KINDS}
    coll_count = {k: 0 for k in _COLL_KINDS}
    _flop_cache: dict[str, float] = {}

    def fusion_flops(comp_name: str) -> float:
        """dot flops inside a fusion body (scale applied by caller)."""
        if comp_name in _flop_cache:
            return _flop_cache[comp_name]
        comp = comps.get(comp_name)
        total = 0.0
        if comp:
            for ln in comp.lines:
                if re.search(r"=\s*\(?[\w\[\],{}]*\s*dot\(", ln) or " dot(" in ln:
                    total += _dot_flops(ln, comp)
                cm = _CALLED_RE.search(ln)
                if cm and "while(" not in ln and cm.group(1) != comp_name:
                    total += fusion_flops(cm.group(1))
        _flop_cache[comp_name] = total
        return total

    def walk(comp_name: str, scale: float, depth: int = 0) -> None:
        nonlocal flops, hbm
        comp = comps.get(comp_name)
        if comp is None or depth > 50:
            return
        for ln in comp.lines:
            body = ln.split("=", 1)[1] if "=" in ln else ln
            # collectives
            matched_coll = False
            for kind in _COLL_KINDS:
                if re.search(rf"\b{kind}(-start)?\(", body) and "-done" not in body:
                    b = _shape_bytes(ln.split(f" {kind}")[0])
                    coll_bytes[kind] += b * scale
                    coll_count[kind] += 1
                    hbm += 2 * b * scale
                    matched_coll = True
            if matched_coll:
                continue
            # while loops: recurse with trip weighting
            if " while(" in body:
                called = dict(re.findall(r"(condition|body)=%?([\w.\-]+)", ln))
                trips = _trip_count(comps.get(called.get("condition", "")))
                if "body" in called:
                    walk(called["body"], scale * trips, depth + 1)
                continue
            # conditionals
            bm = _BRANCHES_RE.search(body)
            if bm:
                for br in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                    walk(br, scale, depth + 1)
                continue
            # dots at top level
            if " dot(" in body:
                flops += _dot_flops(ln, comp) * scale
                out_b = _shape_bytes(body.split(" dot(")[0])
                in_b = sum(_shape_bytes("%s[%s]" % (comp.defs[o][0],
                                                    ",".join(map(str, comp.defs[o][1]))))
                           for o in _operand_names(ln) if o in comp.defs)
                hbm += (out_b + in_b) * scale
                continue
            # fusions / calls: interior dot flops + boundary bytes
            if " fusion(" in body or " call(" in body or "custom-call" in body:
                cm = _CALLED_RE.search(ln)
                if cm:
                    flops += fusion_flops(cm.group(1)) * scale
                    callee = comps.get(cm.group(1))
                    if callee:
                        in_b = sum(_numel(",".join(map(str, dims)))
                                   * DTYPE_BYTES[dt]
                                   for dt, dims in callee.param_shapes)
                        out_b = _shape_bytes(ln.split(" fusion(")[0]
                                             if " fusion(" in body
                                             else ln.split("=", 1)[0] + "=" +
                                             body.split("(", 1)[0])
                        hbm += (in_b + out_b) * scale
                continue
            # other top-level materializing ops: result bytes
            if re.search(r"\b(copy|broadcast|transpose|reshape|convert|"
                         r"dynamic-update-slice|dynamic-slice|slice|pad|"
                         r"concatenate|reduce|convolution|scatter|gather)\(",
                         body):
                if "convolution(" in body:
                    # approximate conv flops: 2 * numel(out) * window elems
                    out = _first_shape(body)
                    win = re.search(r"window=\{size=([\dx]+)", body)
                    k = 1
                    if win:
                        for t in win.group(1).split("x"):
                            k *= int(t)
                    if out:
                        flops += 2.0 * _numel(",".join(map(str, out[1]))) \
                            * k * scale
                hbm += 2 * _shape_bytes(body.split("(", 1)[0]) * scale

    if entry:
        walk(entry, 1.0)
    return HloAnalysis(flops, hbm,
                       sum(coll_bytes.values()),
                       {k: int(v) for k, v in coll_bytes.items()},
                       coll_count)


# legacy wrapper used by early dryrun revisions
def collective_bytes_from_hlo(hlo: str):
    a = analyze_hlo(hlo)

    @dataclasses.dataclass
    class CollectiveStats:
        bytes_by_kind: dict
        total_bytes: int
        count_by_kind: dict

    return CollectiveStats(a.bytes_by_kind, int(a.collective_bytes),
                           a.count_by_kind)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int) -> dict:
    compute = flops / (n_chips * PEAK_FLOPS)
    memory = hbm_bytes / (n_chips * HBM_BW)
    collective = coll_bytes / (n_chips * LINK_BW)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    return terms


def model_flops_train(n_params_active: float, n_tokens: float) -> float:
    """6*N*D rule (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * n_tokens


def model_flops_decode(n_params_active: float, n_tokens: float) -> float:
    """2*N per generated token (one forward)."""
    return 2.0 * n_params_active * n_tokens
