"""Train / serve step builders: microbatch accumulation + SP-NGD update.

``make_train_step(model, opt, accum)`` returns a pure jittable function

    train_step(params, opt_state, batch, flags, lam, lr, mom)
        -> (params, opt_state, metrics)

With ``accum > 1`` the global batch is split into microbatches scanned
sequentially; gradients average and raw factor sums add — the paper's own
statistics-accumulation method for extreme batch sizes (§7.1). The G-type
raw sums are rescaled by 1/accum^2 so the tokens-as-samples normalization
stays exact (each microbatch's dL/ds carries a 1/n_micro, not 1/n_total).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.ngd import SPNGD
from repro.launch import compat


def _check_accum_capture(opt: SPNGD, accum: int) -> None:
    """Fused wire-format capture (FactorSpec.wire_fmt) emits fp8 payloads
    whose microbatch sums are NOT representable (fp8 has no add); refuse
    the scan-accumulation schedules up front instead of silently adding
    quantized payloads."""
    if accum <= 1:
        return
    from repro import quant
    template = jax.eval_shape(opt.fstats_fn)
    wired = [f"{fam}.{k}" for fam, stats in template.items()
             for k, leaf in stats.items() if quant.is_wire(leaf)]
    if wired:
        raise ValueError(
            f"accum={accum} cannot accumulate wire-format statistics "
            f"({', '.join(sorted(wired))}): fp8 payloads do not add across "
            "microbatches. Use accum=1 with fused capture, or dense "
            "capture (FactorSpec.wire_fmt='') with accumulation.")


def make_train_step(model, opt: SPNGD, accum: int = 1) -> Callable:
    _check_accum_capture(opt, accum)

    def train_step(params, opt_state, batch, flags, lam, lr, mom):
        counts = model.site_counts(batch)          # full-batch counts

        if accum == 1:
            loss, aux, grads, raw = opt.grads_and_raw(params, batch)
            loss_mean = loss
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            mb0 = jax.tree.map(lambda x: x[0], micro)
            g_shape = jax.eval_shape(opt.grads_and_raw, params, mb0)
            zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 (g_shape[2], g_shape[3]))

            def body(carry, mb):
                g_acc, r_acc, l_acc = carry
                loss, aux, g, r = opt.grads_and_raw(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                r_acc = jax.tree.map(jnp.add, r_acc, r)
                return (g_acc, r_acc, l_acc + loss), None

            (grads, raw, loss_sum), _ = jax.lax.scan(
                body, (zeros[0], zeros[1], jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            # G-type raw sums: undo the microbatch mean-loss scaling
            raw = {fam: {k: (v if k == "a" else v / (accum * accum))
                         for k, v in stats.items()}
                   for fam, stats in raw.items()}
            loss_mean = loss_sum / accum
            aux = {}

        return opt.apply_update(params, opt_state, grads, raw, counts,
                                flags, lam, lr, mom, loss_mean, aux)

    return train_step


def make_fast_step(model, opt: SPNGD, accum: int = 1) -> Callable:
    """No-capture step (all statistics within their refresh interval)."""
    def fast_step(params, opt_state, batch, lam, lr, mom):
        if accum == 1:
            return opt.step_fast(params, opt_state, batch, lam, lr, mom)
        micro = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)

        def body(carry, mb):
            g_acc, l_acc = carry
            (loss, aux), g = jax.value_and_grad(
                opt.loss_fn, has_aux=True)(params, None, mb)
            return (jax.tree.map(jnp.add, g_acc, g), l_acc + loss), None

        zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), params)
        (grads, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / accum, grads)
        opt_state, curv, extra = opt.fast_curv(opt_state, lam)
        return opt._finish(params, opt_state, grads, curv,
                           lam, lr, mom, loss_sum / accum, {}, {},
                           extra_metrics=extra)

    return fast_step


def make_shardmap_train_step(model, opt: SPNGD, mesh, accum: int = 1,
                             counts_fn=None,
                             manual_axes: str = "auto",
                             comm=None) -> Callable:
    """The paper's Algorithm 3 with EXPLICIT collectives (shard_map over the
    data axes; the model/TP axis stays compiler-managed):

      Stage 1-2: forward/backward on the LOCAL batch shard — gradients and
                 raw factor sums accumulate across microbatches with NO
                 cross-device traffic (GSPMD-auto inserts per-layer
                 all-reduces inside the backward scan; doing it manually
                 defers everything to one sync point).
      Stage 3:   one ``psum`` for the gradients + one reduce-scatter per
                 factor family, scattering the layer axis across the data
                 axes — the ReduceScatterV of the paper. The collective is
                 owned by :class:`repro.comm.FactorReducer`; ``comm``
                 (a :class:`repro.comm.CommConfig`) selects the strategy:
                 dense psum_scatter (default, bit-compatible), ring
                 reduce-scatter over sym-packed triangles, or the fp8-wire
                 ring.
      Stage 4:   inversion + preconditioning run on layer-sharded factors
                 (the sharding hook keeps them scattered).
      Stage 5:   the updated weights' all-gather is GSPMD's job (weights are
                 replicated over data, so the preconditioned update is
                 gathered exactly once).
    """
    from jax.sharding import PartitionSpec as P

    from repro.comm import FactorReducer, Stage4Inverter
    _check_accum_capture(opt, accum)
    reducer = FactorReducer(mesh, manual_axes=manual_axes, comm=comm,
                            template=jax.eval_shape(opt.fstats_fn),
                            sym_fn=opt.sym_stat)
    dp, ndev = reducer.dp, reducer.ndev
    if opt.cfg.inverse_sharding:
        # Stage-4 distribution: the refresh's full-kind inverses run shard-
        # locally over THIS reducer's chunk layout and all-gather. Attached
        # here (not in the optimizer) because ownership is the reducer's.
        opt.set_stage4(Stage4Inverter(reducer, method=opt.cfg.inverse_method,
                                      backend=opt.cfg.backend,
                                      ns_iters=opt.cfg.ns_iters,
                                      ns_tol=opt.cfg.ns_tol))

    def inner(params, batch):
        if accum == 1:
            loss, aux, grads, raw = opt.grads_and_raw(params, batch)
            loss_sum = loss
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            mb0 = jax.tree.map(lambda x: x[0], micro)
            g_shape = jax.eval_shape(opt.grads_and_raw, params, mb0)
            zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 (g_shape[2], g_shape[3]))

            def body(carry, mb):
                g_acc, r_acc, l_acc = carry
                loss, aux, g, r = opt.grads_and_raw(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g),
                        jax.tree.map(jnp.add, r_acc, r),
                        l_acc + loss), None

            (grads, raw, loss_sum), _ = jax.lax.scan(
                body, (zeros[0], zeros[1], jnp.zeros((), jnp.float32)), micro)

        # ---- Stage 3: explicit collectives, once per step ----
        loss = reducer.psum(loss_sum) / (ndev * accum)
        grads = jax.tree.map(lambda g: reducer.psum(g) / (ndev * accum),
                             grads)
        g_scale = 1.0 / (accum * accum * ndev * ndev)
        # undo local-mean-loss scaling BEFORE the reduce (the fp8 wire
        # quantizes what actually travels). Fused wire-format capture
        # already quantized the payload — rescale its per-block scales
        # instead, which is mathematically exact.
        from repro import quant

        def _rescale_g(v):
            if quant.is_wire(v):
                return {"payload": v["payload"],
                        "scale": v["scale"] * g_scale}
            return v * g_scale

        raw = {fam: {k: (v if k == "a" else _rescale_g(v))
                     for k, v in stats.items()}
               for fam, stats in raw.items()}
        return loss, grads, reducer.reduce(raw)

    def train_step(params, opt_state, batch, flags, lam, lr, mom):
        counts = model.site_counts(batch)
        batch_specs = jax.tree.map(
            lambda x: P(dp, *(None,) * (x.ndim - 1)), batch)
        sm = compat.shard_map(
            inner, mesh=mesh,
            in_specs=(P(), batch_specs),
            out_specs=(P(), P(), reducer.out_specs()),
            axis_names=set(dp))
        loss, grads, raw = sm(params, batch)
        return opt.apply_update(params, opt_state, grads, raw, counts,
                                flags, lam, lr, mom, loss, {})

    train_step.reducer = reducer     # launch layer: ledger + tally access
    return train_step


def make_shardmap_fast_step(model, opt: SPNGD, mesh, accum: int = 1,
                            manual_axes: str = "auto",
                            comm=None) -> Callable:
    """Algorithm 1 fast path under the explicit schedule: no statistic
    refreshes this step — backward + ONE gradient psum + stale-preconditioned
    update. This is the steady-state step whose cost the paper drives down to
    ~SGD. The reducer owns the collective axes here too (no factor traffic,
    so the strategy only picks which axes the gradient psum runs over)."""
    from jax.sharding import PartitionSpec as P

    from repro.comm import FactorReducer
    reducer = FactorReducer(mesh, manual_axes=manual_axes, comm=comm)
    dp, ndev = reducer.dp, reducer.ndev

    def inner(params, batch):
        if accum == 1:
            (loss, aux), grads = jax.value_and_grad(
                opt.loss_fn, has_aux=True)(params, None, batch)
            loss_sum = loss
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                (loss, aux), g = jax.value_and_grad(
                    opt.loss_fn, has_aux=True)(params, None, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + loss), None

            zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
        loss = reducer.psum(loss_sum) / (ndev * accum)
        grads = jax.tree.map(lambda g: reducer.psum(g) / (ndev * accum),
                             grads)
        return loss, grads

    def fast_step(params, opt_state, batch, lam, lr, mom):
        batch_specs = jax.tree.map(
            lambda x: P(dp, *(None,) * (x.ndim - 1)), batch)
        sm = compat.shard_map(inner, mesh=mesh, in_specs=(P(), batch_specs),
                              out_specs=(P(), P()), axis_names=set(dp))
        loss, grads = sm(params, batch)
        # fast_curv drains one refresh-pipeline chunk (refresh_chunks > 1)
        # or performs the plain double-buffer activation. The drain runs
        # OUTSIDE the manual region: Stage4Inverter opens its own shard_map
        # for the chunk's shard-local inverses + gathers, exactly as the
        # inline refresh path does.
        opt_state, curv, extra = opt.fast_curv(opt_state, lam)
        return opt._finish(params, opt_state, grads, curv,
                           lam, lr, mom, loss, {}, {}, extra_metrics=extra)

    fast_step.reducer = reducer
    return fast_step


def make_serve_step(model) -> Callable:
    """Single-token decode against a persistent cache."""
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return serve_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        return logits
    return prefill_step


# ---------------------------------------------------------------------------
# overhead-accounting probe (repro.obs; make_report.py's decomposition input)
# ---------------------------------------------------------------------------

def _probe_time(fn, *args, iters: int = 3) -> float:
    """Median wall-µs of ``fn(*args)`` after one compile+warm call."""
    import time as _time
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((_time.perf_counter() - t0) * 1e6)
    return sorted(ts)[len(ts) // 2]


def _overhead_probe(opt, step_j, fast_j, params, state, batch, args,
                    lr_fn, log) -> None:
    """Time the step's stage-isolated building blocks and emit one ``probe``
    event. The monolithic jitted step cannot be decomposed from its own
    wall time, so the probe measures four nested programs — forward/backward
    only, +Stage-2 capture, the fast step, the all-flags refresh step — plus
    a per-factor Stage-4 inversion stand-in (the dryrun ``stage4_report``
    recipe). ``make_report.py`` combines these with the metrics stream's
    measured refresh frequency into the paper's overhead-decomposition
    table (fraction of step time in Stage 2/3/4 vs forward/backward)."""
    import numpy as np

    from repro.core.ngd import _dense_leaf_shape
    from repro.kernels import dispatch

    lr0 = lr_fn(0)
    mom0 = 0.9 * lr0 / args.lr
    lam = args.damping

    fwd_bwd_j = jax.jit(lambda p, b: jax.value_and_grad(
        opt.loss_fn, has_aux=True)(p, None, b))
    capture_j = jax.jit(lambda p, b: opt.grads_and_raw(p, b))
    all_on = {k: jnp.asarray(True) for k in opt.stat_names()}

    fwd_bwd_us = _probe_time(fwd_bwd_j, params, batch)
    capture_us = _probe_time(capture_j, params, batch)
    fast_us = _probe_time(fast_j, params, state, batch, lam, lr0, mom0)
    refresh_us = _probe_time(step_j, params, state, batch, all_on,
                             lam, lr0, mom0)

    # Stage-4 inversion in isolation: one damped_inverse per full-kind
    # factor on an SPD stand-in shaped like the real statistic
    rng = np.random.RandomState(0)
    inv_per_stat = {}
    for fam, stats in jax.eval_shape(opt.fstats_fn).items():
        for key, leaf in stats.items():
            if key not in ("a", "g") or not opt.sym_stat(fam, key):
                continue
            shape = _dense_leaf_shape(leaf)
            b = shape[-1]
            m = rng.randn(*shape[:-1], b).astype(np.float32)
            spd = jnp.asarray(m @ np.swapaxes(m, -1, -2) / b
                              + 0.1 * np.eye(b, dtype=np.float32))
            fn = jax.jit(lambda s: dispatch.damped_inverse(
                s, jnp.asarray(lam, jnp.float32),
                method=opt.cfg.inverse_method, ns_iters=opt.cfg.ns_iters,
                ns_tol=opt.cfg.ns_tol, backend=opt.cfg.backend))
            inv_per_stat[f"{fam}.{key}"] = _probe_time(fn, spd, iters=1)
    log.emit("probe", fwd_bwd_us=fwd_bwd_us, capture_us=capture_us,
             fast_us=fast_us, refresh_us=refresh_us,
             inverse_us=sum(inv_per_stat.values()),
             inverse_us_per_stat=inv_per_stat)


# ---------------------------------------------------------------------------
# CLI launcher: train any --arch (reduced) on the synthetic LM task
# ---------------------------------------------------------------------------

def main():
    import argparse

    from repro.configs import get_config
    from repro.core.stale import IntervalController
    from repro.data.synthetic import token_batches
    from repro.models.transformer import DecoderLM
    from repro.optim.schedules import polynomial_decay

    ap = argparse.ArgumentParser(
        description="SP-NGD trainer (reduced configs on CPU; the full "
                    "configs are exercised via repro.launch.dryrun)")
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=2e-2)
    ap.add_argument("--damping", type=float, default=2.5e-4)
    ap.add_argument("--backend", default="auto",
                    choices=["ref", "pallas", "auto"],
                    help="kernel backend for the SP-NGD hot paths "
                         "(repro.kernels.dispatch); pallas trains attention "
                         "through the fused dq/dk/dv backward kernels "
                         "(residual-saving forward, no recompute pass)")
    from repro.quant import FACTOR_DTYPES
    ap.add_argument("--factor-dtype", default="f32",
                    choices=sorted(FACTOR_DTYPES),
                    help="storage dtype for the X_-1/X_-2 factor history "
                         "and the statistics payload ledger; fp8 variants "
                         "store sym-packed payloads + per-block scales "
                         "(repro.quant) and dequantize on read")
    ap.add_argument("--inverse-method", default="eigh",
                    choices=["eigh", "cholesky", "newton_schulz"],
                    help="Stage-4 factor inversion: direct factorization "
                         "(eigh/cholesky) or the matmul-only Newton-Schulz "
                         "iteration (Pallas kernel under --backend pallas; "
                         "blocks that fail to contract re-solve via eigh)")
    from repro import comm as comm_lib
    ap.add_argument("--comm-strategy", default="dense",
                    choices=comm_lib.STRATEGIES,
                    help="Stage-3 factor reduce strategy (repro.comm): "
                         "dense psum_scatter (bit-compatible default), ring "
                         "reduce-scatter over sym-packed triangles, "
                         "ring_fp8 (fp8 wire payloads + per-block scales, "
                         "f32 accumulation per hop), hier (intra-host f32 "
                         "psum_scatter + inter-host fp8 ring), or fused "
                         "(wire-format payloads emitted by the SYRK "
                         "epilogue). This single-process CLI runs the jit "
                         "schedule (no collectives) — the flag here MODELS "
                         "the wire ledger; the collective itself runs under "
                         "make_shardmap_train_step "
                         "(repro.launch.dryrun --schedule shardmap)")
    ap.add_argument("--wire-dtype", default=None,
                    choices=sorted(comm_lib.WIRE_DTYPES),
                    help="collective wire dtype; defaults to f32 for "
                         "dense/ring and fp8_e4m3 for ring_fp8/hier/fused")
    ap.add_argument("--devices-per-host", type=int, default=None,
                    help="host-topology model for the hier strategy: group "
                         "size of the full-precision intra-host level "
                         "(default: jax.local_device_count())")
    ap.add_argument("--inverse-sharding", action="store_true",
                    help="Stage-4 distribution: invert only the local "
                         "factor shard (FactorReducer chunk ownership) and "
                         "all-gather preconditioners as sym-packed f32 "
                         "triangles. Implies --double-buffer (the pipelined "
                         "mode the paper describes). This single-process "
                         "CLI runs the jit schedule, so the flag here "
                         "MODELS the gather ledger; the sharded inversion "
                         "itself runs under make_shardmap_train_step "
                         "(repro.launch.dryrun --schedule shardmap)")
    ap.add_argument("--double-buffer", action="store_true",
                    help="pipeline refreshes: inverses computed at step t "
                         "activate at t+1 while t consumes the previous "
                         "buffer (Algorithm 2 still governs staleness)")
    ap.add_argument("--refresh-chunks", type=int, default=1,
                    help="chunked refresh pipeline (repro.core.pipeline): "
                         "K>1 turns each refresh into a capture step "
                         "(Stage-2/3 + similarities only) followed by K "
                         "drain chunks of Stage-4 inversions+gathers, one "
                         "fused into each subsequent fast step, activated "
                         "atomically K+1 steps after the capture. Implies "
                         "--double-buffer and floors the refresh interval "
                         "at K+1 so a drain always completes. 1 = inline "
                         "refresh (default)")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-reduced) architecture")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="write the per-step JSONL event stream here "
                         "(repro.obs.MetricsLogger): loss/lr/norms, refresh "
                         "decisions, drained comm-ledger bytes, NS/eigh "
                         "inversion tallies, step-time EMA + p50/p99. "
                         "Console text is unchanged (and mirrored into the "
                         "stream); disabled = zero-cost no-op")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the first "
                         "--profile-steps steps into DIR (stage scopes "
                         "spngd.stage*.* and kernel scopes "
                         "repro.kernels.*[backend] name the regions)")
    ap.add_argument("--profile-steps", type=int, default=3,
                    help="length of the --profile-dir capture window")
    ap.add_argument("--no-overhead-probe", action="store_true",
                    help="skip the stage-isolated timing probe that "
                         "metrics-enabled runs emit for make_report.py's "
                         "overhead-accounting table")
    args = ap.parse_args()

    import dataclasses

    from repro.core.ngd import NGDConfig, SPNGD
    from repro.obs import (STAGE_CHUNK, MetricsLogger, ProfileCapture,
                           inverse_tally)

    log = MetricsLogger(args.metrics_jsonl)
    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, backend=args.backend)
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    log.console(f"arch={args.arch} "
                f"({'full' if args.full_config else 'reduced'}), "
                f"{n / 1e6:.1f}M params")

    inverse_sharding = args.inverse_sharding
    refresh_chunks = max(1, args.refresh_chunks)
    double_buffer = (args.double_buffer or inverse_sharding
                     or refresh_chunks > 1)
    opt = SPNGD(model.loss, model.site_infos(), model.fstats,
                model.site_counts,
                NGDConfig(damping=args.damping, backend=args.backend,
                          inverse_method=args.inverse_method,
                          factor_dtype=FACTOR_DTYPES[args.factor_dtype],
                          inverse_sharding=inverse_sharding,
                          double_buffer=double_buffer,
                          refresh_chunks=refresh_chunks,
                          # metrics runs surface per-block Stage-4
                          # diagnostics; default runs keep the seed tree.
                          # Capture steps run no inversions, so there is
                          # nothing to report under the chunked pipeline
                          inverse_info=log.enabled and refresh_chunks == 1))
    state = opt.init(params)
    comm_cfg = comm_lib.make_comm_config(args.comm_strategy, args.wire_dtype,
                                         backend=args.backend,
                                         devices_per_host=args.devices_per_host)
    ctrl = IntervalController(opt.stat_names(), alpha=0.1,
                              # a drain takes K chunk steps + the flip:
                              # never capture again before it finishes
                              min_interval=(refresh_chunks + 1
                                            if refresh_chunks > 1 else 1),
                              bytes_per_stat=opt.stat_bytes(),
                              wire_bytes_per_stat=opt.wire_bytes(comm_cfg),
                              wire_level_bytes_per_stat=opt.wire_level_bytes(
                                  comm_cfg),
                              gather_bytes_per_stat=(
                                  opt.gather_bytes() if inverse_sharding
                                  else None))
    ctrl.record_comm({"strategy": comm_cfg.strategy,
                      "wire_dtype": comm_cfg.wire_dtype,
                      "inverse_sharding": inverse_sharding,
                      "double_buffer": double_buffer,
                      "refresh_chunks": refresh_chunks})
    data = token_batches(cfg.vocab, args.batch, args.seq, seed=0)
    lr_fn = polynomial_decay(args.lr, 0, args.steps, 4.0)
    step_j = jax.jit(make_train_step(model, opt, accum=args.accum))
    fast_j = jax.jit(make_fast_step(model, opt, accum=args.accum))

    log.emit("run_config", arch=args.arch, full_config=args.full_config,
             n_params=int(n), steps=args.steps, batch=args.batch,
             seq=args.seq, accum=args.accum, lr=args.lr,
             damping=args.damping, backend=args.backend,
             factor_dtype=args.factor_dtype,
             inverse_method=args.inverse_method,
             comm_strategy=comm_cfg.strategy,
             wire_dtype=comm_cfg.wire_dtype,
             inverse_sharding=inverse_sharding,
             double_buffer=double_buffer,
             refresh_chunks=refresh_chunks)
    # per-block-size Stage-4 tallies need each stat's block size, which the
    # on-device info arrays don't carry — read it off the stats template
    block_sizes = {}
    from repro.core.ngd import _dense_leaf_shape
    for fam, stats in jax.eval_shape(opt.fstats_fn).items():
        for key, leaf in stats.items():
            if key in ("a", "g") and opt.sym_stat(fam, key):
                block_sizes[f"{fam}.{key}"] = _dense_leaf_shape(leaf)[-1]
    if log.enabled and not args.no_overhead_probe:
        # dedicated generator: the probe must not advance the training
        # stream (a metrics run sees the same batches as a default run)
        probe_batch = next(token_batches(cfg.vocab, args.batch, args.seq,
                                         seed=1))
        _overhead_probe(opt, step_j, fast_j, params, state, probe_batch,
                        args, lr_fn, log)
    prof = ProfileCapture(args.profile_dir, steps=args.profile_steps)

    import time as _time
    for t in range(1, args.steps + 1):
        batch = next(data)
        lr = lr_fn(t - 1)
        mom = 0.9 * lr / args.lr
        flags = ctrl.flags(t)
        prof.step_start(t)
        t0 = _time.perf_counter()
        if any(flags.values()):
            jflags = {k: jnp.asarray(v) for k, v in flags.items()}
            params, state, m = step_j(params, state, batch, jflags,
                                      args.damping, lr, mom)
            ctrl.update(t, flags, {k: (float(v[0]), float(v[1]))
                                   for k, v in m["sims"].items()})
        else:
            params, state, m = fast_j(params, state, batch,
                                      args.damping, lr, mom)
            ctrl.update(t, flags, {})
        if log.enabled:
            jax.block_until_ready(m["loss"])
            dt = _time.perf_counter() - t0
            # chunked pipeline: refresh-trigger steps are CAPTUREs (no
            # inversion runs inline), so the stream's "refresh" kind —
            # which make_report amortizes the inline Stage-3/4 costs
            # over — honestly goes to zero occurrences
            trigger = any(flags.values())
            kind = ("capture" if trigger and refresh_chunks > 1
                    else "refresh" if trigger else "fast")
            evt = {"kind": kind,
                   "lr": lr, "mom": mom,
                   "n_refreshed": sum(flags.values()),
                   "n_stats": len(flags),
                   "refreshed": sorted(k for k, v in flags.items() if v),
                   "grad_norm": float(m["grad_norm"]),
                   "update_norm": float(m["update_norm"]),
                   "comm": ctrl.drain()}
            if "refresh_inflight" in m:
                # steps until the in-flight refresh activates: K+1 on the
                # capture, K..1 across the drain, 0 when idle
                infl = int(m["refresh_inflight"])
                evt["refresh_inflight"] = infl
                if kind == "fast" and 0 < infl <= refresh_chunks + 1:
                    # per-chunk span: the step window this chunk (or, at
                    # infl == 1, the activation flip) was fused into
                    idx = refresh_chunks + 1 - infl
                    chunk = (opt.pipeline.chunk_names(idx)
                             if idx < refresh_chunks else [])
                    log.emit("span",
                             name=(f"{STAGE_CHUNK}[{idx}]"
                                   if idx < refresh_chunks
                                   else f"{STAGE_CHUNK}[flip]"),
                             start=t0, dur=dt, depth=0, parent=None,
                             step=t, stats=chunk)
            if "inverse_info" in m:
                evt["inverse"] = inverse_tally(m["inverse_info"],
                                               block_sizes)
            log.log_step(t, loss=float(m["loss"]), dt=dt, **evt)
        prof.step_end(t)
        if t % 10 == 0 or t == 1:
            log.console(f"step {t:4d} loss {float(m['loss']):.4f} "
                        f"lr {lr:.4f} "
                        f"refresh {sum(flags.values())}/{len(flags)}")
    prof.stop()
    s = ctrl.summary()
    log.console(f"statistic traffic: {100 * s['reduction_rate']:.1f}% of "
                f"dense; "
                f"modelled wire [{comm_cfg.strategy}/{comm_cfg.wire_dtype}]: "
                f"{s['comm']['total_wire_bytes']} B "
                f"({100 * s['comm']['wire_reduction_rate']:.1f}% of "
                f"refresh-every-step)")
    if inverse_sharding:
        log.console(f"modelled Stage-4 gather (sym-packed f32): "
                    f"{s['comm']['total_gather_bytes']} B")
    log.emit("summary", **ctrl.summary_flat())
    log.close()


if __name__ == "__main__":
    main()
