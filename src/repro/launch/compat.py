"""Version shims for the JAX APIs the launch layer uses.

The repo targets the current JAX surface (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``, dict-valued ``Compiled.cost_analysis``); this
container ships jax 0.4.x where those are still under ``jax.experimental`` or
spelled differently. Every call site goes through this module so the rest of
the codebase reads as if it were written against one API.
"""

from __future__ import annotations

import contextlib
from typing import Callable

import jax

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    if _HAS_AXIS_TYPES:
        types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=types)
    return jax.make_mesh(axis_shapes, axis_names)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # 0.4.x: Mesh itself is the context manager


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              axis_names: set) -> Callable:
    """Partial-manual shard_map: ``axis_names`` are manual, the rest stay
    compiler-managed (GSPMD). Replication checking is off — the SP-NGD
    schedule's out_specs mix scattered and replicated results on purpose."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(axis_names),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - set(axis_names)
    if auto:
        # Partial-manual (GSPMD inside the region) trips an XLA partitioner
        # CHECK ("sharding.IsManualSubgroup()") on this toolchain — run fully
        # manual instead. Axes outside ``axis_names`` are untouched by the
        # body's collectives and by the in/out specs, so results replicate
        # across them and numerics are identical; only compiler-managed TP
        # inside the region is lost on this jax version.
        auto = frozenset()
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict (0.4.x returns a
    per-device list)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
