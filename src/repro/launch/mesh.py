"""Production device meshes.

Target hardware: TPU v5e pods — 256 chips/pod as a (data=16, model=16) mesh;
the multi-pod configuration stacks a leading "pod" axis (2 pods = 512 chips).
Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

from repro.launch import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    >= data*model in the test process)."""
    return compat.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))
