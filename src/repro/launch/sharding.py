"""Sharding policy: maps every parameter / batch / cache / factor array onto
the (pod, data, model) mesh.

Policy (DESIGN.md §7):
* batch dims shard over ("pod","data");
* tensor-parallel: head/ff output dims over "model" (column-parallel up,
  row-parallel down — Megatron-style pairing keeps one all-reduce per block);
* large archs (d_model >= `fsdp_threshold`) additionally shard the weight
  input dim over "data" (FSDP/ZeRO-style 2D sharding: XLA all-gathers
  weights per layer on use);
* K-FAC factor families shard their layer axis over the flattened
  ("data","model") axes — the GSPMD realization of the paper's
  ReduceScatterV -> model-parallel inversion (Stages 3-4);
* optimizer state (velocity, curvature history) inherits the same specs.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def dp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# parameter specs by path pattern
# ---------------------------------------------------------------------------

def param_pspec(path: str, ndim: int, cfg: ArchConfig, *,
                fsdp: bool) -> P:
    """path: '/'-joined parameter path; leading (L,) axis handled by ndim."""
    lead = (None,) * (ndim - 2)       # (L,) for blocks, () for top-level
    d_in_axis = "data" if fsdp else None

    def col(_=None):                  # (..., d_in, d_out): split d_out
        return P(*lead, d_in_axis, "model")

    def row(_=None):                  # (..., d_in, d_out): split d_in
        return P(*lead, "model", d_in_axis)

    p = path
    if re.search(r"embed/table$", p):
        return P(d_in_axis, "model")
    if re.search(r"head/w$", p):
        return P(d_in_axis, "model")
    if re.search(r"proj/w$", p):
        return P(None, "model")
    if re.search(r"attn/(wq|wk|wv)$", p):
        return col()
    if re.search(r"attn/wo$", p):
        return row()
    if re.search(r"attn/(bq|bk|bv)$", p):
        return P(*(None,) * (ndim - 1), "model")
    if re.search(r"mlp/(up|gate)$|moe/sh_(up|gate)$|cm/wk$", p):
        return col()
    if re.search(r"mlp/down$|moe/sh_down$|cm/wv$", p):
        return row()
    if re.search(r"moe/router$", p):
        return P(*lead, None, None)
    if re.search(r"moe/we_(up|gate)$", p):   # (L, E, d, ff)
        return P(None, None, d_in_axis, "model")
    if re.search(r"moe/we_down$", p):        # (L, E, ff, d)
        return P(None, None, "model", d_in_axis)
    if re.search(r"ssm/in_proj$", p):
        return col()
    if re.search(r"ssm/(xdb|out_proj)$", p):
        return row()
    if re.search(r"ssm/dt_proj$", p):
        return col()
    if re.search(r"ssm/(conv_w|dt_bias|d_skip)$", p):
        return P(*(None,) * (ndim - 1), "model")
    if re.search(r"ssm/a_log$", p):
        return P(*(None,) * (ndim - 2), "model", None)
    if re.search(r"tm/(wr|wk|wv|wg)$|cm/wr$", p):
        return col()
    if re.search(r"tm/wo$", p):
        return row()
    if re.search(r"tm/w_lora_a$", p):
        return P(*lead, None, None)
    if re.search(r"tm/w_lora_b$", p):
        return P(*lead, None, None)
    return P()                        # norms, mu vectors, small leaves


def _sanitize(spec: P, shape, mesh) -> P:
    """Drop axis assignments that don't divide the dimension (input
    shardings require exact division; e.g. vocab=32001 can't go 16-way)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axes in zip(shape, parts):
        if axes is None:
            out.append(None)
            continue
        size = _mesh_size(mesh, axes if isinstance(axes, tuple) else (axes,))
        out.append(axes if dim % size == 0 and dim >= size else None)
    return P(*out)


def params_pspecs(params_shape, cfg: ArchConfig, *, mesh=None,
                  fsdp_threshold: int = 6144):
    """Pytree of PartitionSpec matching a params eval_shape pytree."""
    fsdp = cfg.d_model >= fsdp_threshold
    from repro.core.ngd import _flatten_paths

    flat = _flatten_paths(params_shape)
    out = {}
    for p, v in flat.items():
        spec = param_pspec(p, len(v.shape), cfg, fsdp=fsdp)
        if mesh is not None:
            spec = _sanitize(spec, v.shape, mesh)
        out[p] = spec
    from repro.core.ngd import _unflatten_paths
    return _unflatten_paths(out, like=params_shape)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def _mesh_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def _assign(shape, mesh, preferences) -> P:
    """Build a spec by assigning each mesh-axis group to the first listed
    dimension it divides evenly. ``preferences``: [(axes, [dim, ...]), ...]
    in priority order. Input shardings must divide exactly (unlike
    constraints), hence the fallback chain — e.g. long_500k has batch=1, so
    the data axes land on the cache sequence dim instead."""
    spec = [None] * len(shape)
    for axes, dims in preferences:
        size = _mesh_size(mesh, axes)
        for d in dims:
            if spec[d] is None and shape[d] % size == 0 and shape[d] >= size:
                spec[d] = axes
                break
    return P(*spec)


def batch_pspecs(batch_shape, mesh) -> dict:
    dp = dp_axes(mesh)
    out = {}
    for k, v in batch_shape.items():
        if k == "cache":
            out[k] = cache_pspecs(v, mesh)
        elif hasattr(v, "shape") and len(v.shape) >= 2:
            # (B, S, ...): batch over data, else sequence over data
            out[k] = _assign(v.shape, mesh, [(dp, [0, 1])])
        elif hasattr(v, "shape") and len(v.shape) == 1:
            out[k] = _assign(v.shape, mesh, [(dp, [0])])
        else:
            out[k] = P()
    return out


def cache_pspecs(cache_shape, mesh) -> dict:
    """KV cache (L, B, M, KV, hd): batch over data + heads over model when
    divisible; otherwise the sequence dim M absorbs the axes (long_500k has
    batch=1, GQA archs have KV < 16)."""
    dp = dp_axes(mesh)
    out = {}
    for k, v in cache_shape.items():
        s = v.shape
        if k in ("k", "v"):                   # (L, B, M, KV, hd)
            out[k] = _assign(s, mesh, [(dp, [1, 2]), (("model",), [3, 2, 4])])
        elif k == "ssm_h":                    # (L, B, di, N)
            out[k] = _assign(s, mesh, [(dp, [1, 2]), (("model",), [2])])
        elif k == "conv":                     # (L, B, K, di)
            out[k] = _assign(s, mesh, [(dp, [1, 3]), (("model",), [3])])
        elif k == "wkv":                      # (L, B, h, hd, hd)
            out[k] = _assign(s, mesh, [(dp, [1, 2]), (("model",), [2])])
        elif k in ("tm_x", "cm_x"):           # (L, B, 1, d)
            out[k] = _assign(s, mesh, [(dp, [1, 3]), (("model",), [3])])
        elif k == "len":
            out[k] = P()
        else:
            out[k] = P(*(None,) * len(s))
    return out


# ---------------------------------------------------------------------------
# K-FAC factor sharding hook (the Stage 3-4 scatter)
# ---------------------------------------------------------------------------

def _lead_axes(dim: int, mesh, exact: bool = False) -> tuple:
    """Largest prefix of mesh axes whose total shard count fits ``dim``.
    With ``exact=True`` the product must also divide ``dim`` (required for
    input shardings; constraints tolerate uneven/padded sharding)."""
    chosen = []
    prod = 1
    for a in mesh.axis_names:
        nxt = prod * mesh.shape[a]
        if nxt <= dim and (not exact or dim % nxt == 0):
            chosen.append(a)
            prod = nxt
    return tuple(chosen)


def factor_sharding_hook(mesh):
    """Returns hook(family, stat_key, array): factor arrays with a leading
    layer axis get scattered over the mesh axes flattened — each device then
    inverts only its own layer-blocks (paper Stage 4)."""

    def hook(fam, key, x):
        if x.ndim < 1 or not fam.startswith("blk/"):
            return x
        axes = _lead_axes(x.shape[0], mesh)
        if not axes:
            return x
        spec = P(axes, *(None,) * (x.ndim - 1))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return hook


def opt_state_pspecs(opt_state_shape, params_specs, mesh):
    """velocity: like params; curvature: layer axis over the mesh."""

    def curv_spec(x):
        if len(x.shape) >= 1:
            axes = _lead_axes(x.shape[0], mesh, exact=True)
            if axes:
                return P(axes, *(None,) * (len(x.shape) - 1))
        return P()

    out = {"step": P(),
           "velocity": params_specs,
           "curv": jax.tree.map(curv_spec, opt_state_shape["curv"])}
    if "pipeline" in opt_state_shape:
        # raw stat store mirrors the curv factor shapes (leading block
        # axis); cursor/valid are scalars and fall through to P()
        out["pipeline"] = jax.tree.map(curv_spec, opt_state_shape["pipeline"])
    return out
