"""Stage-3 communication subsystem (see :mod:`repro.comm.comm`)."""

from repro.comm.comm import (CommConfig, FactorReducer, STRATEGIES,
                             WIRE_DTYPES, make_comm_config,
                             template_wire_bytes, wire_stat_bytes)

__all__ = ["CommConfig", "FactorReducer", "STRATEGIES", "WIRE_DTYPES",
           "make_comm_config", "template_wire_bytes", "wire_stat_bytes"]
