"""Stage-3/4 communication subsystem (see :mod:`repro.comm.comm` and
:mod:`repro.comm.stage4`)."""

from repro.comm.comm import (CommConfig, FactorReducer, STRATEGIES,
                             WIRE_DTYPES, gather_stat_bytes, hier_split,
                             make_comm_config, template_gather_bytes,
                             template_wire_bytes, template_wire_level_bytes,
                             wire_stat_bytes, wire_stat_level_bytes)
from repro.comm.stage4 import Stage4Inverter

__all__ = ["CommConfig", "FactorReducer", "STRATEGIES", "Stage4Inverter",
           "WIRE_DTYPES", "gather_stat_bytes", "hier_split",
           "make_comm_config", "template_gather_bytes",
           "template_wire_bytes", "template_wire_level_bytes",
           "wire_stat_bytes", "wire_stat_level_bytes"]
