"""Stage-3 communication subsystem (see :mod:`repro.comm.comm`)."""

from repro.comm.comm import (CommConfig, FactorReducer, STRATEGIES,
                             WIRE_DTYPES, hier_split, make_comm_config,
                             template_wire_bytes, template_wire_level_bytes,
                             wire_stat_bytes, wire_stat_level_bytes)

__all__ = ["CommConfig", "FactorReducer", "STRATEGIES", "WIRE_DTYPES",
           "hier_split", "make_comm_config", "template_wire_bytes",
           "template_wire_level_bytes", "wire_stat_bytes",
           "wire_stat_level_bytes"]
