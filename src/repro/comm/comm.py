"""Stage-3 communication subsystem: pluggable factor reduce strategies.

The paper's scalability argument (Alg. 3, §5.2) hangs on Stage 3 being ONE
ReduceScatterV per factor family per refresh. This module single-sources
everything about that collective that used to be welded into
``launch/train.py``: which mesh axes a statistic scatters over, the
``PartitionSpec`` the shard_map out_specs must mirror, the wire payload
layout, and the reduce implementation itself.

Strategies
----------
``dense``
    ``jax.lax.psum_scatter(v, axes, scatter_dimension=0, tiled=True)`` on the
    raw f32 blocked array — bit-compatible with the pre-refactor behaviour
    and the default everywhere.
``ring``
    ppermute-based ring reduce-scatter. Symmetric blocked factors sym-pack
    their trailing ``(b, b)`` axes to ``t = b(b+1)/2`` rows *before* the ring
    (paper §5.2), so the wire moves the triangle only — ~0.5x the dense wire
    volume; non-symmetric statistics ride the ring as dense f32 rows. Same
    summation order per chunk as a hardware ring, so results match ``dense``
    to f32 reduction-reorder noise (not bit-identical).
``ring_fp8``
    The ``ring`` schedule with fp8 wire payloads for the symmetric factors:
    each hop's partial sum quantizes per block (one scale per packed row,
    via the ``ring_hop_pack``/``ring_hop_unpack`` dispatch ops reusing
    :mod:`repro.kernels.quant_pack`), travels as fp8 payload + f32 scale,
    and dequantizes to f32 on arrival before the local chunk is added — f32
    accumulation at every hop, so quantization error grows linearly in the
    hop count (p-1 hops x <= amax/28 for e4m3) instead of compounding.
    Non-symmetric statistics (diag / unit-wise — a rounding-sensitive,
    byte-wise negligible minority) stay on the f32 ring.
``hier``
    Two-level reduce following host topology (Osawa et al. 2019/2020): the
    device group of size p splits into H hosts x D local devices
    (``CommConfig.devices_per_host``, defaulting from
    ``jax.local_device_count()``; D = gcd(devices_per_host, p)). Level 1 is
    an intra-host ``psum_scatter`` at full precision (f32 sym-packed
    triangle for symmetric factors); level 2 is D parallel inter-host rings
    over host peers with the configured wire dtype (fp8 by default). A
    static chunk permutation before level 1 makes the final chunk ownership
    identical to ``psum_scatter(tiled=True)``, so out_specs are strategy
    invariant. Hop count and fp8 wire bytes scale with H (hosts), not p
    (devices); the ledger itemizes the two levels separately
    (:meth:`FactorReducer.wire_bytes_per_stat_levels`).
``fused``
    Consumes **pre-packed wire payloads** produced by the fused SYRK
    epilogue (``factor_sum_wire``): symmetric factors arrive at the reducer
    already sym-packed + fp8-quantized as ``{"payload", "scale"}`` dicts, so
    the raw f32 factor sum never round-trips HBM and the reducer performs
    ZERO ``ring_hop_pack`` dispatches. The exchange is a tiled fp8
    ``all_to_all`` (payload + scales) followed by an f32 dequant-and-sum
    over source devices — one rounding per source contribution, independent
    of group size. Non-symmetric statistics (not wire-captured) ride the
    dense ``psum_scatter`` path.

Replication fallback
--------------------
A statistic whose leading dim is not divisible by any data-axis subset
cannot scatter and falls back to a plain ``psum`` (full replication). That
used to happen silently; the reducer now records the tally at construction
time (the decision is static — pure shape arithmetic), logs it once, and
hands it to :meth:`repro.core.stale.IntervalController.record_comm` so
``summary()`` exposes it.

The byte ledger convention: ``wire_stat_bytes`` counts the logical payload
one full reduction moves per device (the same convention as the storage
ledger) — the ring's (p-1)/p send factor applies equally to XLA's own
reduce-scatter implementation and is deliberately left out. Under ``hier``
the per-level breakdown prices level 1 at the full (packed) f32 array and
level 2 at 1/D of the wire-encoded array (each device enters the inter-host
ring holding only its 1/D slice); flat strategies report (0, 0) levels.

Stage-4 gather
--------------
The reducer also owns the return leg: under sharded Stage-4
(:class:`repro.comm.stage4.Stage4Inverter`) each device inverts only the
factor chunk the reduce-scatter left it with, and the preconditioners come
back via :meth:`FactorReducer.gather_stat` — an ``all_gather(tiled=True)``
over the SAME axes the statistic scattered over, so ownership is
strategy-invariant by construction. Symmetric factors gather as sym-packed
f32 triangles. The gather wire NEVER quantizes, regardless of
``wire_dtype``: inverse-factor rounding error feeds straight into the
update direction (there is no later accumulation to average it out), so
fp8 is reserved for the Stage-3 statistics leg.
``gather_stat_bytes`` / :meth:`FactorReducer.gather_bytes_per_stat` price
this leg for the IntervalController ledger (0 for replicated stats — no
gather runs).

Chunked-drain interaction
-------------------------
Under the chunked refresh pipeline (``NGDConfig.refresh_chunks > 1``,
:mod:`repro.core.pipeline`) Stage 3 is untouched: the capture step still
runs ONE reduce per factor family, exactly as inline. Only the return leg
moves — each drain chunk re-enters :class:`~repro.comm.stage4.Stage4Inverter`
for its own (family, stat) subset, so ``gather_stat`` runs once per chunk
instead of once per refresh, over the same axes with the same payloads.
Total gather bytes per refresh are identical (the chunks partition the
stats); only the per-step timing changes. Scatter decisions, out_specs,
and the byte ledger are therefore pipeline-invariant and need no
re-pricing.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

logger = logging.getLogger(__name__)

STRATEGIES = ("dense", "ring", "ring_fp8", "hier", "fused")
WIRE_DTYPES = ("f32", "fp8_e4m3", "fp8_e5m2")

# strategies whose inter-host / hop wire defaults to fp8 (make_comm_config)
_FP8_DEFAULT_STRATEGIES = ("ring_fp8", "hier", "fused")

# unroll the ring hop loop up to this many hops: a Python loop over static
# hop indices lets XLA pipeline each hop's pack+ppermute against the next
# chunk add, where lax.fori_loop serializes them behind a loop carry
_RING_UNROLL_MAX_HOPS = 32


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Stage-3 collective configuration (one per training run)."""
    strategy: str = "dense"       # one of STRATEGIES
    wire_dtype: str = "f32"       # "f32" | "fp8_e4m3" | "fp8_e5m2"
    fp8_scale_mode: str = "fp32"  # per-block scale mode for fp8 hops
    backend: Optional[str] = None  # kernel backend for hop pack/unpack
    # host-topology model for "hier": local devices per host. None defaults
    # to jax.local_device_count(); the 8-virtual-device subprocess benches
    # override it (e.g. 4 -> a simulated 2-host x 4-device mesh).
    devices_per_host: Optional[int] = None

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown comm strategy {self.strategy!r}; "
                             f"expected {STRATEGIES}")
        if self.wire_dtype not in WIRE_DTYPES:
            raise ValueError(f"unknown wire dtype {self.wire_dtype!r}; "
                             f"expected {WIRE_DTYPES}")
        if self.strategy in ("ring_fp8", "fused") \
                and self.wire_dtype == "f32":
            raise ValueError(f"{self.strategy} needs an fp8 wire_dtype "
                             "(fp8_e4m3 | fp8_e5m2); use make_comm_config "
                             "to get the e4m3 default")
        if self.strategy in ("dense", "ring") and self.wire_dtype != "f32":
            raise ValueError(f"strategy {self.strategy!r} moves f32 on the "
                             f"wire; --wire-dtype {self.wire_dtype} only "
                             "applies to ring_fp8 / hier / fused")
        if self.devices_per_host is not None and self.devices_per_host < 1:
            raise ValueError("devices_per_host must be >= 1 (or None to "
                             "default from jax.local_device_count())")

    @property
    def wire_fmt(self) -> Optional[str]:
        """fp8 format key for the hop codec ("e4m3"/"e5m2"), None for f32."""
        if self.wire_dtype.startswith("fp8_"):
            return self.wire_dtype[4:]
        return None

    def local_devices(self) -> int:
        """Resolved devices-per-host (the "hier" level-1 group width)."""
        if self.devices_per_host is not None:
            return self.devices_per_host
        return jax.local_device_count()


def make_comm_config(strategy: str, wire_dtype: Optional[str] = None,
                     fp8_scale_mode: str = "fp32",
                     backend: Optional[str] = None,
                     devices_per_host: Optional[int] = None) -> CommConfig:
    """CLI-facing constructor: fills the per-strategy default wire dtype
    (f32 for dense/ring, e4m3 for ring_fp8/hier/fused) when ``wire_dtype``
    is None."""
    if wire_dtype is None:
        wire_dtype = ("fp8_e4m3" if strategy in _FP8_DEFAULT_STRATEGIES
                      else "f32")
    return CommConfig(strategy=strategy, wire_dtype=wire_dtype,
                      fp8_scale_mode=fp8_scale_mode, backend=backend,
                      devices_per_host=devices_per_host)


def hier_split(cfg: CommConfig, group_size: int) -> tuple[int, int]:
    """(D, H): intra-host width and host count for a device group of
    ``group_size`` under ``cfg``'s topology model. D divides the group
    evenly (gcd with the configured local width); D*H == group_size."""
    import math
    d = math.gcd(max(cfg.local_devices(), 1), group_size)
    return d, group_size // d


def _leaf_shape(leaf) -> tuple:
    """Template-leaf shape in DENSE terms: wire-format dicts report the
    shape their payload decodes to, so scatter decisions and out_specs are
    capture-format invariant."""
    from repro import quant
    if quant.is_wire(leaf):
        return quant.wire_dense_shape(leaf)
    return tuple(leaf.shape)


# ---------------------------------------------------------------------------
# Wire-volume accounting (the IntervalController's wire-bytes column)
# ---------------------------------------------------------------------------

def template_wire_bytes(template: dict, sym_fn: Callable[[str, str], bool],
                        cfg: CommConfig,
                        scattered_fn: Optional[Callable] = None,
                        group_size: Optional[int] = None) -> dict[str, int]:
    """Per-statistic wire bytes for a whole ``fstats`` template — the ONE
    walk behind both ``SPNGD.wire_bytes`` (mesh-less: assumes the paper's
    everything-scatters layout) and ``FactorReducer.wire_bytes_per_stat``
    (prices this mesh's replication fallbacks at dense f32 via
    ``scattered_fn(name) -> bool``). ``group_size`` models the scatter
    group for the hier level split (flat strategies ignore it)."""
    out = {}
    for fam, stats in template.items():
        for key, leaf in stats.items():
            name = f"{fam}.{key}"
            scattered = scattered_fn(name) if scattered_fn else True
            out[name] = wire_stat_bytes(_leaf_shape(leaf), sym_fn(fam, key),
                                        cfg, scattered=scattered,
                                        group_size=group_size)
    return out


def template_wire_level_bytes(template: dict,
                              sym_fn: Callable[[str, str], bool],
                              cfg: CommConfig,
                              scattered_fn: Optional[Callable] = None,
                              group_size: Optional[int] = None
                              ) -> dict[str, tuple[int, int]]:
    """Per-statistic (intra-host, inter-host) wire bytes for a whole
    ``fstats`` template — the mesh-less counterpart of
    ``FactorReducer.wire_bytes_per_stat_levels`` (same everything-scatters
    assumption as :func:`template_wire_bytes`)."""
    out = {}
    for fam, stats in template.items():
        for key, leaf in stats.items():
            name = f"{fam}.{key}"
            scattered = scattered_fn(name) if scattered_fn else True
            out[name] = wire_stat_level_bytes(
                _leaf_shape(leaf), sym_fn(fam, key), cfg,
                scattered=scattered, group_size=group_size)
    return out


def wire_stat_bytes(shape: tuple, symmetric: bool, cfg: CommConfig,
                    scattered: bool = True,
                    group_size: Optional[int] = None) -> int:
    """Bytes one full Stage-3 reduction of this statistic moves per device.

    ``dense`` (and any replication fallback) moves the raw blocked f32
    array; ``ring`` moves the sym-packed f32 triangle for symmetric factors;
    ``ring_fp8`` and ``fused`` move fp8 payload + one f32 scale per packed
    row; ``hier`` is the sum of its two levels (``wire_stat_level_bytes``,
    priced for a group of ``group_size`` devices — default: one full host).
    The ring's (p-1)/p factor is deliberately not applied (see module
    docs)."""
    from repro import quant
    from repro.core.stale import sym_packed_bytes
    dense = int(np.prod(shape, dtype=np.int64)) * 4
    sym = symmetric and len(shape) >= 2 and shape[-1] == shape[-2]
    if cfg.strategy == "dense" or not scattered:
        return dense
    if cfg.strategy == "hier":
        # always the sum of the two levels — including non-sym stats,
        # which ride level 1 dense and level 2 as a dense 1/D slice
        intra, inter = wire_stat_level_bytes(shape, symmetric, cfg,
                                             scattered=scattered,
                                             group_size=group_size)
        return intra + inter
    if not sym:
        return dense
    if cfg.strategy == "ring":
        return sym_packed_bytes(shape, dtype_bytes=4)
    # ring_fp8 / fused: wire tile == the fp8 storage tile, one formula
    return quant.encoded_nbytes(shape, symmetric=True)


def gather_stat_bytes(shape: tuple, symmetric: bool,
                      scattered: bool = True) -> int:
    """Bytes one Stage-4 preconditioner all-gather moves per device.

    Symmetric blocked inverses travel as sym-packed f32 triangles (the
    ``gather_stat`` wire format); anything else travels dense f32. Always
    f32 — the inverse wire never quantizes (see module docs). A replicated
    statistic was inverted everywhere, so nothing gathers (0 bytes)."""
    from repro.core.stale import sym_packed_bytes
    if not scattered:
        return 0
    if symmetric and len(shape) >= 2 and shape[-1] == shape[-2]:
        return sym_packed_bytes(shape, dtype_bytes=4)
    return int(np.prod(shape, dtype=np.int64)) * 4


def template_gather_bytes(template: dict,
                          sym_fn: Callable[[str, str], bool],
                          scattered_fn: Optional[Callable] = None
                          ) -> dict[str, int]:
    """Per-statistic Stage-4 gather bytes for a whole ``fstats`` template —
    the gather-leg counterpart of :func:`template_wire_bytes` (mesh-less:
    assumes everything scatters unless ``scattered_fn`` says otherwise).
    Only full-kind Kronecker factors (symmetric "a"/"g" stats) are inverted
    shard-locally and gathered; every other statistic prices 0."""
    out = {}
    for fam, stats in template.items():
        for key, leaf in stats.items():
            name = f"{fam}.{key}"
            if key not in ("a", "g") or not sym_fn(fam, key):
                out[name] = 0
                continue
            scattered = scattered_fn(name) if scattered_fn else True
            out[name] = gather_stat_bytes(_leaf_shape(leaf), True,
                                          scattered=scattered)
    return out


def wire_stat_level_bytes(shape: tuple, symmetric: bool, cfg: CommConfig,
                          scattered: bool = True,
                          group_size: Optional[int] = None
                          ) -> tuple[int, int]:
    """(intra-host, inter-host) wire bytes for one Stage-3 reduction of this
    statistic. Only ``hier`` has a meaningful split — flat strategies return
    ``(0, 0)`` so downstream reports can distinguish "no hierarchy ran" from
    "zero bytes". Replication fallbacks bill their dense f32 psum to the
    inter-host column (the worst wire). Level 1 moves the full (sym-packed)
    f32 array across the D-device host group; level 2 moves each device's
    1/D slice around the H-host ring in the configured wire dtype."""
    from repro import quant
    from repro.core.stale import sym_packed_bytes
    if cfg.strategy != "hier":
        return (0, 0)
    dense = int(np.prod(shape, dtype=np.int64)) * 4
    if not scattered:
        return (0, dense)
    if group_size is None:
        group_size = cfg.local_devices()
    d, h = hier_split(cfg, max(group_size, 1))
    sym = symmetric and len(shape) >= 2 and shape[-1] == shape[-2]
    if not sym:
        return (dense if d > 1 else 0, dense // d if h > 1 else 0)
    packed = sym_packed_bytes(shape, dtype_bytes=4)
    intra = packed if d > 1 else 0
    if h <= 1:
        return (intra, 0)
    if cfg.wire_fmt is not None:
        return (intra, quant.encoded_nbytes(shape, symmetric=True) // d)
    return (intra, packed // d)


# ---------------------------------------------------------------------------
# The reducer
# ---------------------------------------------------------------------------

class FactorReducer:
    """Owns every Stage-3 decision for one (mesh, manual_axes, CommConfig).

    Construction is host-side and eager: the scatter decision per statistic
    is pure shape arithmetic over the ``fstats`` template, so the
    replication tally, the shard_map out_specs and the wire-byte ledger all
    exist before anything traces. The traced entry points
    (:meth:`reduce`, :meth:`reduce_stat`, :meth:`psum`) are called INSIDE
    the shard_map region.
    """

    def __init__(self, mesh, *, manual_axes: str = "auto",
                 comm: Optional[CommConfig] = None,
                 template: Optional[dict] = None,
                 sym_fn: Optional[Callable[[str, str], bool]] = None):
        self.mesh = mesh
        self.comm = comm or CommConfig()
        # "all": the paper's pure-DP replica layout — every mesh axis is
        # manual and factors scatter over all of them. "auto"/"dp": only
        # the data axes are manual; the model axis stays GSPMD (TP).
        if manual_axes == "all":
            self.dp = tuple(mesh.axis_names)
        else:
            self.dp = tuple(a for a in ("pod", "data")
                            if a in mesh.axis_names)
        self.ndev = 1
        for a in self.dp:
            self.ndev *= mesh.shape[a]
        self.sym_fn = sym_fn or (lambda fam, key: False)
        self.template = template
        self._decisions: dict[str, tuple] = {}
        self.replicated: list[str] = []
        if template is not None:
            for fam, stats in template.items():
                for key, leaf in stats.items():
                    shape = _leaf_shape(leaf)
                    axes = (self.scatter_axes(shape[0])
                            if len(shape) else ())
                    self._decisions[f"{fam}.{key}"] = axes
                    if len(shape) and not axes:
                        self.replicated.append(f"{fam}.{key}")
            if self.replicated and self.ndev > 1:
                logger.warning(
                    "Stage-3: %d/%d statistics cannot scatter over %s "
                    "(leading dim not divisible) and fall back to fully "
                    "replicated psum: %s", len(self.replicated),
                    len(self._decisions), self.dp,
                    ", ".join(sorted(self.replicated)))

    # ---- decisions (host-side, shape-static) ----

    def scatter_axes(self, dim: int) -> tuple:
        """Largest subset of the data axes whose size divides ``dim`` —
        the single source of the scatter decision (previously triplicated
        across reduce_stat / _scatter_axes / _raw_specs in train.py)."""
        full = 1
        for a in self.dp:
            full *= self.mesh.shape[a]
        if full and dim % full == 0 and dim >= full:
            return self.dp
        if "data" in self.dp and dim % self.mesh.shape["data"] == 0 \
                and dim >= self.mesh.shape["data"]:
            return ("data",)
        return ()

    def out_spec(self, shape: tuple):
        """shard_map out-spec mirroring the scatter decision for ``shape``."""
        from jax.sharding import PartitionSpec as P
        axes = self.scatter_axes(shape[0]) if len(shape) else ()
        return (P(axes, *(None,) * (len(shape) - 1)) if axes else P())

    def out_specs(self):
        """Out-spec tree for the whole ``fstats`` template. Wire-format
        leaves spec their DECODED dense shape: the reducer dequantizes
        after the collective, so shard_map bodies always return dense f32
        regardless of the capture format."""
        if self.template is None:
            raise ValueError("FactorReducer needs a template for out_specs")
        return {fam: {k: self.out_spec(_leaf_shape(leaf))
                      for k, leaf in stats.items()}
                for fam, stats in self.template.items()}

    def group_size(self, axes: tuple) -> int:
        """Number of devices in the scatter group ``axes`` spans."""
        p = 1
        for a in axes:
            p *= self.mesh.shape[a]
        return p

    def scatter_report(self) -> dict:
        """Host-side tally for IntervalController.record_comm / logging."""
        report = {
            "strategy": self.comm.strategy,
            "wire_dtype": self.comm.wire_dtype,
            "dp_axes": list(self.dp),
            "n_stats": len(self._decisions),
            "n_replicated": len(self.replicated),
            "replicated_stats": sorted(self.replicated),
        }
        if self.comm.strategy == "hier":
            d, h = hier_split(self.comm, self.ndev)
            report["hier_topology"] = {"devices_per_host": d, "hosts": h}
        return report

    def wire_bytes_per_stat(self) -> dict[str, int]:
        """Per-refresh wire bytes of each statistic under this reducer's
        ACTUAL decisions (replication fallbacks cost the full dense f32;
        ``hier`` levels are priced for each stat's actual group size)."""
        if self.template is None:
            raise ValueError("FactorReducer needs a template for wire bytes")
        out = {}
        for fam, stats in self.template.items():
            for key, leaf in stats.items():
                name = f"{fam}.{key}"
                axes = self._decisions.get(name, ())
                out[name] = wire_stat_bytes(
                    _leaf_shape(leaf), self.sym_fn(fam, key), self.comm,
                    scattered=bool(axes),
                    group_size=self.group_size(axes) if axes else None)
        return out

    def gather_bytes_per_stat(self) -> dict[str, int]:
        """Per-refresh Stage-4 all-gather bytes per statistic under this
        reducer's ACTUAL scatter decisions (a replication fallback never
        gathers: the inverse was computed everywhere). Nonzero only for the
        full-kind symmetric "a"/"g" factors that Stage-4 shards."""
        if self.template is None:
            raise ValueError("FactorReducer needs a template for gather "
                             "bytes")
        out = {}
        for fam, stats in self.template.items():
            for key, leaf in stats.items():
                name = f"{fam}.{key}"
                if key not in ("a", "g") or not self.sym_fn(fam, key):
                    out[name] = 0
                    continue
                axes = self._decisions.get(name, ())
                out[name] = gather_stat_bytes(_leaf_shape(leaf), True,
                                              scattered=bool(axes))
        return out

    def wire_bytes_per_stat_levels(self) -> dict[str, tuple[int, int]]:
        """Per-refresh (intra-host, inter-host) wire bytes per statistic —
        the level breakdown behind the IntervalController's hier ledger
        columns. Flat strategies report (0, 0) for every stat."""
        if self.template is None:
            raise ValueError("FactorReducer needs a template for wire bytes")
        out = {}
        for fam, stats in self.template.items():
            for key, leaf in stats.items():
                name = f"{fam}.{key}"
                axes = self._decisions.get(name, ())
                out[name] = wire_stat_level_bytes(
                    _leaf_shape(leaf), self.sym_fn(fam, key), self.comm,
                    scattered=bool(axes),
                    group_size=self.group_size(axes) if axes else None)
        return out

    # ---- traced entry points (call inside the shard_map region) ----

    def psum(self, x):
        """Plain all-reduce over the data axes (gradients / loss)."""
        return jax.lax.psum(x, self.dp)

    def reduce_stat(self, fam: str, key: str, v) -> jax.Array:
        """One statistic's Stage-3 reduce: scatter when divisible (strategy
        applies), fully-replicated psum otherwise. Wire-format dicts from
        the fused SYRK epilogue take the pre-packed all_to_all path and
        come back decoded to dense f32."""
        from repro import quant
        from repro.obs.tracing import STAGE_REDUCE
        # strategy-tagged stage scope: trace-viewer A/Bs of comm strategies
        # line up under one stable prefix
        with jax.named_scope(
                f"{STAGE_REDUCE}[{self.comm.strategy}:{fam}.{key}]"):
            if quant.is_wire(v):
                return self._fused_wire(v)
            axes = self.scatter_axes(v.shape[0]) if v.ndim >= 1 else ()
            if not axes:
                return jax.lax.psum(v, self.dp)
            if self.comm.strategy in ("dense", "fused"):
                # fused: non-wire stats (diag / unit-wise, never
                # wire-captured) stay on the exact dense path
                v = jax.lax.psum_scatter(v, axes, scatter_dimension=0,
                                         tiled=True)
            elif self.comm.strategy == "hier":
                v = self._hier(v, axes, symmetric=self.sym_fn(fam, key))
            else:
                v = self._ring(v, axes, symmetric=self.sym_fn(fam, key))
            rest = tuple(a for a in self.dp if a not in axes)
            if rest:
                v = jax.lax.psum(v, rest)
            return v

    def reduce(self, raw: dict) -> dict:
        """Reduce a whole raw-statistics tree ({family: {key: array}})."""
        return {fam: {k: self.reduce_stat(fam, k, v)
                      for k, v in stats.items()}
                for fam, stats in raw.items()}

    def gather_stat(self, fam: str, key: str, v: jax.Array,
                    axes: tuple) -> jax.Array:
        """Stage-4 return leg: all-gather a shard-local preconditioner back
        to the full leading dim, over the SAME ``axes`` its statistic
        scattered over (pass the host-side decision — inside the manual
        region ``v.shape[0]`` is the shard size, so the decision cannot be
        recomputed here). Symmetric blocks move the sym-packed f32 triangle
        on the wire; the gather never quantizes (module docs). Chunk order
        matches ``psum_scatter(tiled=True)`` ownership, so gather(invert(
        scatter(x))) is a layout round-trip for every strategy."""
        from repro.core import kfac
        from repro.obs.tracing import STAGE_GATHER
        if not axes:
            return v
        with jax.named_scope(f"{STAGE_GATHER}[{fam}.{key}]"):
            sym = self.sym_fn(fam, key) and v.ndim >= 3 \
                and v.shape[-1] == v.shape[-2]
            b = v.shape[-1] if sym else 0
            if sym:
                v = kfac.sym_pack(v.astype(jnp.float32))  # wire = triangle
            an = axes if len(axes) > 1 else axes[0]
            v = jax.lax.all_gather(v, an, axis=0, tiled=True)
            return kfac.sym_unpack(v, b) if sym else v

    # ---- the ring ----

    def _ring(self, v: jax.Array, axes: tuple, *,
              symmetric: bool) -> jax.Array:
        """Ring reduce-scatter of ``v`` along dim 0 over the (possibly
        multi-axis) device group ``axes``; chunk assignment matches
        ``psum_scatter(..., tiled=True)`` so out_specs are shared with the
        dense strategy."""
        from repro.core import kfac
        p = 1
        for a in axes:
            p *= self.mesh.shape[a]
        sym = symmetric and v.ndim >= 3 and v.shape[-1] == v.shape[-2]
        b = v.shape[-1] if sym else 0
        if sym:
            v = kfac.sym_pack(v.astype(jnp.float32))   # wire = triangle only
        else:
            v = v.astype(jnp.float32)
        if p > 1:
            v = _ring_reduce_scatter(
                v, axes if len(axes) > 1 else axes[0], p,
                fmt=self.comm.wire_fmt if sym else None,
                scale_mode=self.comm.fp8_scale_mode,
                backend=self.comm.backend)
        return kfac.sym_unpack(v, b) if sym else v

    # ---- the two-level hierarchical reduce ----

    def _hier(self, v: jax.Array, axes: tuple, *,
              symmetric: bool) -> jax.Array:
        """Two-level reduce-scatter of ``v`` along dim 0: full-precision
        ``psum_scatter`` across each D-device host group, then D disjoint
        H-host rings (fp8 wire for symmetric factors) over host peers.
        Final chunk ownership matches ``psum_scatter(tiled=True)``, so
        out_specs are shared with every other strategy."""
        from repro.core import kfac
        p = self.group_size(axes)
        sym = symmetric and v.ndim >= 3 and v.shape[-1] == v.shape[-2]
        b = v.shape[-1] if sym else 0
        if sym:
            v = kfac.sym_pack(v.astype(jnp.float32))   # wire = triangle only
        else:
            v = v.astype(jnp.float32)
        if p > 1:
            an = axes if len(axes) > 1 else axes[0]
            d_loc, h = hier_split(self.comm, p)
            d0 = v.shape[0]
            r = d0 // p
            if d_loc > 1 and h > 1:
                # chunk permutation: after the intra-host scatter, device
                # (host h0, local l) must hold the STRIDED chunk set
                # {h'*D + l}; permuting chunks (h', l) -> (l, h') up front
                # makes the contiguous level-1 tiles exactly those sets,
                # and the level-2 ring then lands chunk h0*D + l on flat
                # device h0*D + l — the dense tiled ownership
                v = v.reshape((h, d_loc, r) + v.shape[1:])
                v = jnp.swapaxes(v, 0, 1).reshape((d0,) + v.shape[3:])
            if d_loc > 1:
                groups = ([[h0 * d_loc + l for l in range(d_loc)]
                           for h0 in range(h)] if h > 1 else None)
                v = jax.lax.psum_scatter(v, an, scatter_dimension=0,
                                         tiled=True,
                                         axis_index_groups=groups)
            if h > 1:
                idx = jax.lax.axis_index(an)
                # D disjoint rings, one per local index l: host h0 forwards
                # to host h0+1 at the same local slot
                perm = [(h0 * d_loc + l, ((h0 + 1) % h) * d_loc + l)
                        for h0 in range(h) for l in range(d_loc)]
                v = _ring_reduce_scatter(
                    v, an, h,
                    fmt=self.comm.wire_fmt if sym else None,
                    scale_mode=self.comm.fp8_scale_mode,
                    backend=self.comm.backend,
                    perm=perm, group_index=idx // d_loc)
        return kfac.sym_unpack(v, b) if sym else v

    # ---- the fused pre-packed path ----

    def _fused_wire(self, entry: dict) -> jax.Array:
        """Reduce one pre-packed wire-format stat (``{"payload", "scale"}``
        from the fused SYRK epilogue): tiled fp8 ``all_to_all`` exchange,
        then f32 dequant-and-sum over source devices, then unpack to dense
        blocks. No ``ring_hop_pack`` runs — quantization happened exactly
        once, inside the factor-sum kernel."""
        from repro import quant
        from repro.core import kfac
        from repro.kernels import dispatch
        payload, scale = entry["payload"], entry["scale"]
        b = quant.tri_rows(payload.shape[-1])
        backend = self.comm.backend
        axes = self.scatter_axes(payload.shape[0]) if payload.ndim else ()
        p = self.group_size(axes) if axes else 1
        if not axes or p == 1:
            v = kfac.sym_unpack(
                dispatch.ring_hop_unpack(payload, scale, backend=backend),
                b)
            return jax.lax.psum(v, self.dp)
        an = axes if len(axes) > 1 else axes[0]
        payload = jax.lax.all_to_all(payload, an, split_axis=0,
                                     concat_axis=0, tiled=True)
        scale = jax.lax.all_to_all(scale, an, split_axis=0,
                                   concat_axis=0, tiled=True)
        v = dispatch.ring_hop_unpack(payload, scale, backend=backend)
        c = v.shape[0] // p
        v = jnp.sum(v.reshape((p, c) + v.shape[1:]), axis=0)
        v = kfac.sym_unpack(v, b)
        rest = tuple(a for a in self.dp if a not in axes)
        if rest:
            v = jax.lax.psum(v, rest)
        return v


def _ring_reduce_scatter(v: jax.Array, axis_name, p: int, *,
                         fmt: Optional[str], scale_mode: str,
                         backend: Optional[str],
                         perm: Optional[list] = None,
                         group_index=None) -> jax.Array:
    """p-1-hop ring reduce-scatter along dim 0 (divisible by ``p``).

    Device with group index ``i`` ends holding chunk ``i`` fully reduced
    (the ``tiled=True`` psum_scatter layout). With ``fmt`` set, every hop's
    partial sum travels as fp8 payload + per-row f32 scale (the
    ring_hop_pack/unpack dispatch ops); the accumulator itself stays f32,
    so quantization error is one rounding per hop, not compounding.

    ``perm`` / ``group_index`` generalize the ring to disjoint sub-rings
    over one mesh axis group (the hier strategy's D parallel inter-host
    rings): ``perm`` lists every (src, dst) device pair and ``group_index``
    is this device's position within ITS ring of size ``p``.
    """
    from repro.kernels import dispatch
    d = v.shape[0]
    c = d // p
    idx = jax.lax.axis_index(axis_name) if group_index is None \
        else group_index
    if fmt is None and perm is None:
        # f32 wire has no per-hop codec, so nothing forces the manual hop
        # loop: psum_scatter over the packed rows moves the SAME wire bytes
        # and IS a ring reduce-scatter on real interconnects — at one
        # collective's latency instead of p-1 serialized ppermutes (the
        # rest of the ring-vs-dense wall-clock regression after the unroll
        # below). The fp8 wire keeps the hop loop — per-hop requantization
        # of the partial sum is its contract — and so do sub-group rings
        # (perm set), whose chunk ownership is the caller's permutation.
        return jax.lax.psum_scatter(v, axis_name, scatter_dimension=0,
                                    tiled=True)
    if perm is None:
        perm = [(j, (j + 1) % p) for j in range(p)]

    def chunk(k):
        return jax.lax.dynamic_slice_in_dim(v, k * c, c, axis=0)

    def body(s, acc):
        if fmt is not None:
            payload, scale = dispatch.ring_hop_pack(
                acc, fmt=fmt, scale_mode=scale_mode, backend=backend)
            payload = jax.lax.ppermute(payload, axis_name, perm)
            scale = jax.lax.ppermute(scale, axis_name, perm)
            acc = dispatch.ring_hop_unpack(payload, scale, backend=backend)
        else:
            acc = jax.lax.ppermute(acc, axis_name, perm)
        # chunk received at the end of step s is (idx - 2 - s) mod p; the
        # local contribution joins in f32
        return acc + chunk(jnp.mod(idx + 2 * p - 2 - s, p))

    # each device seeds the ring with its local chunk (idx - 1) mod p; after
    # p-1 hops that chunk has visited every device and landed on its owner
    acc = chunk(jnp.mod(idx + p - 1, p))
    if p - 1 <= _RING_UNROLL_MAX_HOPS:
        # unrolled hops carry STATIC step indices: XLA overlaps each hop's
        # pack/ppermute with the neighbouring chunk adds instead of
        # serializing everything behind a fori_loop carry — this was the
        # 3.0x ring-vs-dense wall-clock regression at p=8
        for s in range(p - 1):
            acc = body(s, acc)
        return acc
    return jax.lax.fori_loop(0, p - 1, body, acc)
