"""Stage-3 communication subsystem: pluggable factor reduce strategies.

The paper's scalability argument (Alg. 3, §5.2) hangs on Stage 3 being ONE
ReduceScatterV per factor family per refresh. This module single-sources
everything about that collective that used to be welded into
``launch/train.py``: which mesh axes a statistic scatters over, the
``PartitionSpec`` the shard_map out_specs must mirror, the wire payload
layout, and the reduce implementation itself.

Strategies
----------
``dense``
    ``jax.lax.psum_scatter(v, axes, scatter_dimension=0, tiled=True)`` on the
    raw f32 blocked array — bit-compatible with the pre-refactor behaviour
    and the default everywhere.
``ring``
    ppermute-based ring reduce-scatter. Symmetric blocked factors sym-pack
    their trailing ``(b, b)`` axes to ``t = b(b+1)/2`` rows *before* the ring
    (paper §5.2), so the wire moves the triangle only — ~0.5x the dense wire
    volume; non-symmetric statistics ride the ring as dense f32 rows. Same
    summation order per chunk as a hardware ring, so results match ``dense``
    to f32 reduction-reorder noise (not bit-identical).
``ring_fp8``
    The ``ring`` schedule with fp8 wire payloads for the symmetric factors:
    each hop's partial sum quantizes per block (one scale per packed row,
    via the ``ring_hop_pack``/``ring_hop_unpack`` dispatch ops reusing
    :mod:`repro.kernels.quant_pack`), travels as fp8 payload + f32 scale,
    and dequantizes to f32 on arrival before the local chunk is added — f32
    accumulation at every hop, so quantization error grows linearly in the
    hop count (p-1 hops x <= amax/28 for e4m3) instead of compounding.
    Non-symmetric statistics (diag / unit-wise — a rounding-sensitive,
    byte-wise negligible minority) stay on the f32 ring.

Replication fallback
--------------------
A statistic whose leading dim is not divisible by any data-axis subset
cannot scatter and falls back to a plain ``psum`` (full replication). That
used to happen silently; the reducer now records the tally at construction
time (the decision is static — pure shape arithmetic), logs it once, and
hands it to :meth:`repro.core.stale.IntervalController.record_comm` so
``summary()`` exposes it.

The byte ledger convention: ``wire_stat_bytes`` counts the logical payload
one full reduction moves per device (the same convention as the storage
ledger) — the ring's (p-1)/p send factor applies equally to XLA's own
reduce-scatter implementation and is deliberately left out.

The planned fused SYRK-epilogue remote-DMA ring kernel (ROADMAP) registers
as a fourth strategy here: it replaces :meth:`FactorReducer._ring` with a
kernel that DMAs hop payloads peer-to-peer out of the factor-sum epilogue,
and nothing in ``launch/train.py`` changes.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

logger = logging.getLogger(__name__)

STRATEGIES = ("dense", "ring", "ring_fp8")
WIRE_DTYPES = ("f32", "fp8_e4m3", "fp8_e5m2")


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Stage-3 collective configuration (one per training run)."""
    strategy: str = "dense"       # "dense" | "ring" | "ring_fp8"
    wire_dtype: str = "f32"       # "f32" | "fp8_e4m3" | "fp8_e5m2"
    fp8_scale_mode: str = "fp32"  # per-block scale mode for fp8 hops
    backend: Optional[str] = None  # kernel backend for hop pack/unpack

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown comm strategy {self.strategy!r}; "
                             f"expected {STRATEGIES}")
        if self.wire_dtype not in WIRE_DTYPES:
            raise ValueError(f"unknown wire dtype {self.wire_dtype!r}; "
                             f"expected {WIRE_DTYPES}")
        if self.strategy == "ring_fp8" and self.wire_dtype == "f32":
            raise ValueError("ring_fp8 needs an fp8 wire_dtype "
                             "(fp8_e4m3 | fp8_e5m2); use make_comm_config "
                             "to get the e4m3 default")
        if self.strategy in ("dense", "ring") and self.wire_dtype != "f32":
            raise ValueError(f"strategy {self.strategy!r} moves f32 on the "
                             f"wire; --wire-dtype {self.wire_dtype} only "
                             "applies to ring_fp8")

    @property
    def wire_fmt(self) -> Optional[str]:
        """fp8 format key for the hop codec ("e4m3"/"e5m2"), None for f32."""
        if self.wire_dtype.startswith("fp8_"):
            return self.wire_dtype[4:]
        return None


def make_comm_config(strategy: str, wire_dtype: Optional[str] = None,
                     fp8_scale_mode: str = "fp32",
                     backend: Optional[str] = None) -> CommConfig:
    """CLI-facing constructor: fills the per-strategy default wire dtype
    (f32 for dense/ring, e4m3 for ring_fp8) when ``wire_dtype`` is None."""
    if wire_dtype is None:
        wire_dtype = "fp8_e4m3" if strategy == "ring_fp8" else "f32"
    return CommConfig(strategy=strategy, wire_dtype=wire_dtype,
                      fp8_scale_mode=fp8_scale_mode, backend=backend)


# ---------------------------------------------------------------------------
# Wire-volume accounting (the IntervalController's wire-bytes column)
# ---------------------------------------------------------------------------

def template_wire_bytes(template: dict, sym_fn: Callable[[str, str], bool],
                        cfg: CommConfig,
                        scattered_fn: Optional[Callable] = None
                        ) -> dict[str, int]:
    """Per-statistic wire bytes for a whole ``fstats`` template — the ONE
    walk behind both ``SPNGD.wire_bytes`` (mesh-less: assumes the paper's
    everything-scatters layout) and ``FactorReducer.wire_bytes_per_stat``
    (prices this mesh's replication fallbacks at dense f32 via
    ``scattered_fn(name) -> bool``)."""
    out = {}
    for fam, stats in template.items():
        for key, leaf in stats.items():
            name = f"{fam}.{key}"
            scattered = scattered_fn(name) if scattered_fn else True
            out[name] = wire_stat_bytes(leaf.shape, sym_fn(fam, key), cfg,
                                        scattered=scattered)
    return out


def wire_stat_bytes(shape: tuple, symmetric: bool, cfg: CommConfig,
                    scattered: bool = True) -> int:
    """Bytes one full Stage-3 reduction of this statistic moves per device.

    ``dense`` (and any replication fallback) moves the raw blocked f32
    array; ``ring`` moves the sym-packed f32 triangle for symmetric factors;
    ``ring_fp8`` moves fp8 payload + one f32 scale per packed row. The
    ring's (p-1)/p factor is deliberately not applied (see module docs)."""
    from repro import quant
    from repro.core.stale import sym_packed_bytes
    dense = int(np.prod(shape, dtype=np.int64)) * 4
    sym = symmetric and len(shape) >= 2 and shape[-1] == shape[-2]
    if cfg.strategy == "dense" or not scattered or not sym:
        return dense
    if cfg.strategy == "ring":
        return sym_packed_bytes(shape, dtype_bytes=4)
    # ring_fp8 wire tile == the fp8 storage tile: one accounting formula
    return quant.encoded_nbytes(shape, symmetric=True)


# ---------------------------------------------------------------------------
# The reducer
# ---------------------------------------------------------------------------

class FactorReducer:
    """Owns every Stage-3 decision for one (mesh, manual_axes, CommConfig).

    Construction is host-side and eager: the scatter decision per statistic
    is pure shape arithmetic over the ``fstats`` template, so the
    replication tally, the shard_map out_specs and the wire-byte ledger all
    exist before anything traces. The traced entry points
    (:meth:`reduce`, :meth:`reduce_stat`, :meth:`psum`) are called INSIDE
    the shard_map region.
    """

    def __init__(self, mesh, *, manual_axes: str = "auto",
                 comm: Optional[CommConfig] = None,
                 template: Optional[dict] = None,
                 sym_fn: Optional[Callable[[str, str], bool]] = None):
        self.mesh = mesh
        self.comm = comm or CommConfig()
        # "all": the paper's pure-DP replica layout — every mesh axis is
        # manual and factors scatter over all of them. "auto"/"dp": only
        # the data axes are manual; the model axis stays GSPMD (TP).
        if manual_axes == "all":
            self.dp = tuple(mesh.axis_names)
        else:
            self.dp = tuple(a for a in ("pod", "data")
                            if a in mesh.axis_names)
        self.ndev = 1
        for a in self.dp:
            self.ndev *= mesh.shape[a]
        self.sym_fn = sym_fn or (lambda fam, key: False)
        self.template = template
        self._decisions: dict[str, tuple] = {}
        self.replicated: list[str] = []
        if template is not None:
            for fam, stats in template.items():
                for key, leaf in stats.items():
                    axes = (self.scatter_axes(leaf.shape[0])
                            if len(leaf.shape) else ())
                    self._decisions[f"{fam}.{key}"] = axes
                    if len(leaf.shape) and not axes:
                        self.replicated.append(f"{fam}.{key}")
            if self.replicated and self.ndev > 1:
                logger.warning(
                    "Stage-3: %d/%d statistics cannot scatter over %s "
                    "(leading dim not divisible) and fall back to fully "
                    "replicated psum: %s", len(self.replicated),
                    len(self._decisions), self.dp,
                    ", ".join(sorted(self.replicated)))

    # ---- decisions (host-side, shape-static) ----

    def scatter_axes(self, dim: int) -> tuple:
        """Largest subset of the data axes whose size divides ``dim`` —
        the single source of the scatter decision (previously triplicated
        across reduce_stat / _scatter_axes / _raw_specs in train.py)."""
        full = 1
        for a in self.dp:
            full *= self.mesh.shape[a]
        if full and dim % full == 0 and dim >= full:
            return self.dp
        if "data" in self.dp and dim % self.mesh.shape["data"] == 0 \
                and dim >= self.mesh.shape["data"]:
            return ("data",)
        return ()

    def out_spec(self, shape: tuple):
        """shard_map out-spec mirroring the scatter decision for ``shape``."""
        from jax.sharding import PartitionSpec as P
        axes = self.scatter_axes(shape[0]) if len(shape) else ()
        return (P(axes, *(None,) * (len(shape) - 1)) if axes else P())

    def out_specs(self):
        """Out-spec tree for the whole ``fstats`` template."""
        if self.template is None:
            raise ValueError("FactorReducer needs a template for out_specs")
        return {fam: {k: self.out_spec(leaf.shape)
                      for k, leaf in stats.items()}
                for fam, stats in self.template.items()}

    def scatter_report(self) -> dict:
        """Host-side tally for IntervalController.record_comm / logging."""
        return {
            "strategy": self.comm.strategy,
            "wire_dtype": self.comm.wire_dtype,
            "dp_axes": list(self.dp),
            "n_stats": len(self._decisions),
            "n_replicated": len(self.replicated),
            "replicated_stats": sorted(self.replicated),
        }

    def wire_bytes_per_stat(self) -> dict[str, int]:
        """Per-refresh wire bytes of each statistic under this reducer's
        ACTUAL decisions (replication fallbacks cost the full dense f32)."""
        if self.template is None:
            raise ValueError("FactorReducer needs a template for wire bytes")
        return template_wire_bytes(
            self.template, self.sym_fn, self.comm,
            scattered_fn=lambda name: bool(self._decisions.get(name)))

    # ---- traced entry points (call inside the shard_map region) ----

    def psum(self, x):
        """Plain all-reduce over the data axes (gradients / loss)."""
        return jax.lax.psum(x, self.dp)

    def reduce_stat(self, fam: str, key: str, v: jax.Array) -> jax.Array:
        """One statistic's Stage-3 reduce: scatter when divisible (strategy
        applies), fully-replicated psum otherwise."""
        axes = self.scatter_axes(v.shape[0]) if v.ndim >= 1 else ()
        if not axes:
            return jax.lax.psum(v, self.dp)
        if self.comm.strategy == "dense":
            v = jax.lax.psum_scatter(v, axes, scatter_dimension=0,
                                     tiled=True)
        else:
            v = self._ring(v, axes, symmetric=self.sym_fn(fam, key))
        rest = tuple(a for a in self.dp if a not in axes)
        if rest:
            v = jax.lax.psum(v, rest)
        return v

    def reduce(self, raw: dict) -> dict:
        """Reduce a whole raw-statistics tree ({family: {key: array}})."""
        return {fam: {k: self.reduce_stat(fam, k, v)
                      for k, v in stats.items()}
                for fam, stats in raw.items()}

    # ---- the ring ----

    def _ring(self, v: jax.Array, axes: tuple, *,
              symmetric: bool) -> jax.Array:
        """Ring reduce-scatter of ``v`` along dim 0 over the (possibly
        multi-axis) device group ``axes``; chunk assignment matches
        ``psum_scatter(..., tiled=True)`` so out_specs are shared with the
        dense strategy."""
        from repro.core import kfac
        p = 1
        for a in axes:
            p *= self.mesh.shape[a]
        sym = symmetric and v.ndim >= 3 and v.shape[-1] == v.shape[-2]
        b = v.shape[-1] if sym else 0
        if sym:
            v = kfac.sym_pack(v.astype(jnp.float32))   # wire = triangle only
        else:
            v = v.astype(jnp.float32)
        if p > 1:
            v = _ring_reduce_scatter(
                v, axes if len(axes) > 1 else axes[0], p,
                fmt=self.comm.wire_fmt if sym else None,
                scale_mode=self.comm.fp8_scale_mode,
                backend=self.comm.backend)
        return kfac.sym_unpack(v, b) if sym else v


def _ring_reduce_scatter(v: jax.Array, axis_name, p: int, *,
                         fmt: Optional[str], scale_mode: str,
                         backend: Optional[str]) -> jax.Array:
    """p-1-hop ring reduce-scatter along dim 0 (divisible by ``p``).

    Device with group index ``i`` ends holding chunk ``i`` fully reduced
    (the ``tiled=True`` psum_scatter layout). With ``fmt`` set, every hop's
    partial sum travels as fp8 payload + per-row f32 scale (the
    ring_hop_pack/unpack dispatch ops); the accumulator itself stays f32,
    so quantization error is one rounding per hop, not compounding.
    """
    from repro.kernels import dispatch
    d = v.shape[0]
    c = d // p
    idx = jax.lax.axis_index(axis_name)
    perm = [(j, (j + 1) % p) for j in range(p)]

    def chunk(k):
        return jax.lax.dynamic_slice_in_dim(v, k * c, c, axis=0)

    def body(s, acc):
        if fmt is not None:
            payload, scale = dispatch.ring_hop_pack(
                acc, fmt=fmt, scale_mode=scale_mode, backend=backend)
            payload = jax.lax.ppermute(payload, axis_name, perm)
            scale = jax.lax.ppermute(scale, axis_name, perm)
            acc = dispatch.ring_hop_unpack(payload, scale, backend=backend)
        else:
            acc = jax.lax.ppermute(acc, axis_name, perm)
        # chunk received at the end of step s is (idx - 2 - s) mod p; the
        # local contribution joins in f32
        return acc + chunk(jnp.mod(idx + 2 * p - 2 - s, p))

    # each device seeds the ring with its local chunk (idx - 1) mod p; after
    # p-1 hops that chunk has visited every device and landed on its owner
    acc = chunk(jnp.mod(idx + p - 1, p))
    return jax.lax.fori_loop(0, p - 1, body, acc)
