"""Stage-4 distribution: shard-local inversion + preconditioner gather.

The paper's negligible-overhead claim (§5.2, Osawa et al. 2018) distributes
the Kronecker-factor inversions layer-wise: after Stage 3's ReduceScatterV
each device holds a disjoint chunk of every factor family's leading (layer)
axis, so it inverts ONLY that chunk and the preconditioners return via one
all-gather — the redundant-inverse FLOPs per device drop ~1/p.

:class:`Stage4Inverter` wraps that contract around
``repro.kernels.dispatch.damped_inverse``:

* **Ownership is the reducer's chunk assignment.** The scatter decision
  (``FactorReducer.scatter_axes``) and the ``psum_scatter(tiled=True)``
  chunk layout are reused verbatim, so inversion ownership is invariant
  across ``dense``/``ring``/``ring_fp8``/``hier``/``fused`` — group index
  ``i`` inverts contiguous chunk ``i`` of the leading dim, always.
* **The gather is a :mod:`repro.comm` collective.**
  ``FactorReducer.gather_stat`` moves sym-packed f32 triangles (never
  quantized — inverse rounding error feeds the update direction directly)
  and its bytes are itemized in the wire ledger via
  ``FactorReducer.gather_bytes_per_stat``.
* **Observability rides ``return_info``.** ``invert(..., return_info=True)``
  returns the gathered per-block ``ns_res``/``ns_converged`` PLUS an
  ``owner`` vector tagging which group index inverted each leading chunk
  (-1 everywhere on the replicated fallback) — the test harness's proof
  that no device inverted outside its shard.

``invert`` opens its own ``shard_map`` (the optimizer calls it at the
GSPMD level, inside the refresh ``lax.cond`` — the factors already LEFT
the Stage-3 manual region scattered, so this region just re-binds the same
layout). Statistics whose leading dim could not scatter fall back to the
replicated inverse, exactly the pre-sharding behaviour.

The same property makes ``invert`` callable from the chunked refresh
pipeline's ``lax.switch`` branches (``refresh_chunks > 1``,
:mod:`repro.core.pipeline`): each drain chunk invokes it for its subset of
full-kind stats from a fast step's GSPMD level, one chunk per step. The
per-call contract is unchanged — ownership, gather axes, and wire bytes
per stat are identical to the inline refresh; the pipeline only changes
WHEN each stat's invert+gather executes, not what it does.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.comm import FactorReducer


def _batch_damp(damp, stat_ndim: int) -> jax.Array:
    """Right-pad ``damp`` with singleton dims until it aligns with the
    stat's batch dims ``stat.shape[:-2]`` (leading-aligned). The optimizer
    hands damp either scalar or leading-(layer-)shaped; a bare
    ``damp[..., None]`` is only correct when the stat carries exactly one
    block axis past the damp's — against a 3-D stat with a per-leading damp
    it would silently broadcast an enlarged batch instead of erroring."""
    d = jnp.asarray(damp, jnp.float32)
    while d.ndim < stat_ndim - 2:
        d = d[..., None]
    return d


def _group_index(axes: tuple, mesh) -> jax.Array:
    """Flat index of this device within the scatter group ``axes`` spans,
    row-major in axis order — the ``psum_scatter(tiled=True)`` chunk owner.
    (Built from per-axis ``axis_index`` so it never relies on tuple
    axis-name support.)"""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


class Stage4Inverter:
    """Shard-local damped inversion over a :class:`FactorReducer` layout.

    Construction is host-side and cheap; :meth:`invert` is the traced entry
    point the optimizer's refresh calls per full-kind factor. One instance
    per (reducer, inversion config) — the step builder attaches it via
    ``SPNGD.set_stage4`` when ``NGDConfig.inverse_sharding`` is on.
    """

    def __init__(self, reducer: FactorReducer, *, method: str = "eigh",
                 backend: str = "auto", ns_iters: int = 40,
                 ns_tol: float = 1e-4):
        self.reducer = reducer
        self.mesh = reducer.mesh
        self.method = method
        self.backend = backend
        self.ns_iters = ns_iters
        self.ns_tol = ns_tol

    # ---- host-side ownership map (what the tests assert against) ----

    def owners(self, dim0: int) -> np.ndarray:
        """Expected chunk owner (group index) per leading index, or -1
        everywhere when ``dim0`` cannot scatter (replicated inversion)."""
        axes = self.reducer.scatter_axes(dim0)
        p = self.reducer.group_size(axes) if axes else 1
        if not axes or p <= 1:
            return np.full((dim0,), -1, np.int32)
        return np.repeat(np.arange(p, dtype=np.int32), dim0 // p)

    # ---- traced entry point ----

    def _replicated(self, stat, damp, return_info):
        from repro.kernels import dispatch
        out = dispatch.damped_inverse(
            stat, _batch_damp(damp, stat.ndim), method=self.method,
            backend=self.backend,
            ns_iters=self.ns_iters, ns_tol=self.ns_tol,
            return_info=return_info)
        if not return_info:
            return out
        inv, info = out
        info = dict(info)
        info["owner"] = jnp.full(stat.shape[:1], -1, jnp.int32)
        return inv, info

    def invert(self, stat: jax.Array, damp: jax.Array, *, fam: str,
               key: str, return_info: bool = False):
        """Damped inverse of a full-kind blocked factor ``stat``
        ((lead..., nb, b, b)): each device inverts its reducer-owned chunk
        of the leading dim, then the preconditioner all-gathers
        (``FactorReducer.gather_stat``). Numerically identical to the
        replicated inverse — sharding only partitions the block batch."""
        from jax.sharding import PartitionSpec as P

        from repro.kernels import dispatch
        from repro.launch import compat
        from repro.obs.tracing import STAGE_INVERSE

        axes = self.reducer.scatter_axes(stat.shape[0]) \
            if stat.ndim >= 3 else ()
        if not axes or self.reducer.group_size(axes) <= 1:
            with jax.named_scope(f"{STAGE_INVERSE}[replicated:{fam}.{key}]"):
                return self._replicated(stat, damp, return_info)

        reducer, mesh = self.reducer, self.mesh
        method, backend = self.method, self.backend
        ns_iters, ns_tol = self.ns_iters, self.ns_tol
        # damp (pi-corrected sqrt-damping) has the factor's leading shape
        # when the family carries a layer axis; scalar damp stays replicated
        damp = jnp.asarray(damp, jnp.float32)
        damp_sharded = damp.ndim >= 1 and damp.shape[0] == stat.shape[0]
        stat_spec = P(axes, *(None,) * (stat.ndim - 1))
        damp_spec = (P(axes, *(None,) * (damp.ndim - 1))
                     if damp_sharded else P())

        def local(s, d):
            inv, info = dispatch.damped_inverse(
                s, _batch_damp(d, s.ndim), method=method, backend=backend,
                ns_iters=ns_iters, ns_tol=ns_tol, return_info=True)
            inv = reducer.gather_stat(fam, key, inv, axes)
            if not return_info:
                return inv
            gi = _group_index(axes, mesh)
            an = axes if len(axes) > 1 else axes[0]
            gathered = {
                k: jax.lax.all_gather(v, an, axis=0, tiled=True)
                for k, v in info.items()}
            gathered["owner"] = jax.lax.all_gather(
                jnp.full((s.shape[0],), gi, jnp.int32), an, axis=0,
                tiled=True)
            return inv, gathered

        out_specs = (P(), {k: P() for k in ("ns_res", "ns_converged",
                                            "owner")}) \
            if return_info else P()
        sm = compat.shard_map(local, mesh=mesh,
                              in_specs=(stat_spec, damp_spec),
                              out_specs=out_specs, axis_names=set(axes))
        with jax.named_scope(f"{STAGE_INVERSE}[sharded:{fam}.{key}]"):
            return sm(stat, damp)
