"""Minimal dependency-free checkpointing: pytrees -> .npz + structure file.

Handles params, optimizer state (including the curvature factors / inverses,
so a restore resumes with warm statistics — important because Algorithm 1's
intervals assume continuity), and host-side controller state (JSON).

Extension dtypes (bf16, the fp8 factor-history payloads) are NOT preserved
by ``np.savez`` — they reload as opaque void dtypes — so leaves with an
ml_dtypes dtype are stored as unsigned-int bit views with the true dtype
name appended to the key (``...|payload@float8_e4m3fn``); restore views the
bits back. Bit-exact round trip for every dtype in the tree.

The chunked refresh pipeline's state (``opt_state["pipeline"]``: cursor,
captured raw stats, valid latches — all jnp leaves) flattens through the
same path with no special casing, so a checkpoint taken mid-drain resumes
bit-identically at the same chunk index (pinned by
tests/test_checkpoint_roundtrip.py). ``SPNGD.upgrade_state`` handles the
cross-config cases: it seeds a fresh idle pipeline into pre-pipeline
checkpoints and drops the key when resuming with ``refresh_chunks == 1``.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# numpy-native kinds that np.savez round-trips faithfully
_NATIVE_KINDS = frozenset("fiub")


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            if "@" in k or "|" in k:
                raise ValueError(f"checkpoint key {k!r} may not contain "
                                 f"'@' or '|' (reserved separators)")
            out.update(_flatten(tree[k], f"{prefix}{k}|"))
    else:
        leaf = np.asarray(tree)
        if leaf.dtype.kind not in _NATIVE_KINDS:      # ml_dtypes extension
            name = leaf.dtype.name
            leaf = leaf.view(np.dtype(f"u{leaf.dtype.itemsize}"))
            out[f"{prefix[:-1]}@{name}"] = leaf
        else:
            out[prefix[:-1]] = leaf
    return out


def _unflatten(flat: dict) -> dict:
    import ml_dtypes  # jax hard-depends on it; the extension-dtype registry
    root: dict = {}
    for key, v in flat.items():
        key, _, dtype_name = key.partition("@")
        if dtype_name:
            v = np.asarray(v).view(np.dtype(getattr(ml_dtypes, dtype_name)))
        parts = key.split("|")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(v)
    return root


def save_checkpoint(ckpt_dir: str, step: int, params: Any,
                    opt_state: Optional[Any] = None,
                    controller: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    np.savez(path + ".params.npz", **_flatten(params))
    if opt_state is not None:
        np.savez(path + ".opt.npz", **_flatten(opt_state))
    if controller is not None:
        with open(path + ".ctrl.json", "w") as f:
            json.dump(controller, f)
    with open(os.path.join(ckpt_dir, "LATEST"), "w") as f:
        f.write(str(step))
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    marker = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        return int(f.read().strip())


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None):
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    params = _unflatten(dict(np.load(path + ".params.npz")))
    opt_state = None
    if os.path.exists(path + ".opt.npz"):
        opt_state = _unflatten(dict(np.load(path + ".opt.npz")))
    controller = None
    if os.path.exists(path + ".ctrl.json"):
        with open(path + ".ctrl.json") as f:
            controller = json.load(f)
    return {"step": step, "params": params, "opt_state": opt_state,
            "controller": controller}
