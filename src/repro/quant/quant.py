"""fp8 factor-history / comm-payload quantization (per-block scales).

The paper makes curvature cheap in two places: §4.3 keeps stale factor
history (X_-1, X_-2) resident in optimizer state, and §5.2 symmetry-packs
the Stage-3 reduce-scatter payload. This module quantizes both to fp8 with
per-block scales, halving stale memory and communication bytes *on top of*
the triangular packing.

Format contract
---------------
* A **stat** is one factor-family array: a full Kronecker factor in the
  blocked ``(lead..., nb, b, b)`` layout (symmetric per block), or a
  diagonal / unit-wise statistic whose trailing axes are not square.
* Symmetric stats are stored **sym-packed**: the lower triangle of each
  ``(b, b)`` block flattens to ``t = b(b+1)/2`` values (``kfac.sym_pack``
  order), then quantizes with ONE scale per block — the scale granularity
  matches the §5.2 communication granularity, so the same payload serves as
  both the resident history and the reduce-scatter message.
* Non-symmetric stats quantize over their last axis with one scale per row.
* ``scale = amax / FMT_MAX`` as fp32 (``scale_mode="fp32"``), or rounded up
  to a power of two (``scale_mode="pow2"``: the scale application becomes an
  exact exponent shift; payload loses ≤ 1 bit of headroom). All-zero blocks
  get scale 1 so decode is exact and no division blows up.
* Values are clipped to ±FMT_MAX before the cast: e4m3 (``float8_e4m3fn``)
  has no inf and overflows to NaN, so the clip is load-bearing.
* **e4m3 vs e5m2**: factor second moments are non-negative with modest
  per-block dynamic range once scaled — precision (3 mantissa bits) beats
  range, so e4m3 is the default. e5m2 exists for gradient-scale statistics
  whose per-block range can exceed e4m3's 2^±8 span.
* **Dequantize-on-read**: decode always returns f32; nothing downstream
  (Frobenius distances, damped inverses) ever computes in fp8.

The encoded representation is a plain dict ``{"payload", "scale"}`` so it
checkpoints, shards and ``tree.map``s like every other piece of optimizer
state. The hot encode/decode path for symmetric stats routes through the
kernel dispatch layer (``fp8_pack`` / ``fp8_unpack`` — ref jnp here, Pallas
in :mod:`repro.kernels.quant_pack`), degrading op-by-op on CPU like every
other kernel.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

FORMATS: dict[str, Any] = {
    "e4m3": jnp.float8_e4m3fn,
    "e5m2": jnp.float8_e5m2,
}

# largest finite magnitude per format (e4m3fn has no inf: 448 then NaN)
FMT_MAX: dict[str, float] = {"e4m3": 448.0, "e5m2": 57344.0}

# scale = amax * (1/FMT_MAX) as an explicit constant multiply: XLA rewrites
# division-by-constant to reciprocal-multiply under jit but not eagerly, so
# an explicit multiply keeps ref and Pallas scales bit-identical
FMT_INV_MAX: dict[str, float] = {k: 1.0 / v for k, v in FMT_MAX.items()}

# bytes per payload element / per-block scale (f32)
PAYLOAD_BYTES = 1
SCALE_BYTES = 4

# CLI spelling -> NGDConfig.factor_dtype value (the single source for the
# --factor-dtype flags on repro.launch.train / repro.launch.dryrun)
FACTOR_DTYPES: dict[str, Any] = {
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
    "fp8_e4m3": "fp8_e4m3",
    "fp8_e5m2": "fp8_e5m2",
}


def parse_factor_dtype(factor_dtype: Any) -> Optional[str]:
    """``NGDConfig.factor_dtype`` -> fp8 format key, or None for plain
    dtypes (f32 / bf16 history stays a dense ``astype``)."""
    if isinstance(factor_dtype, str):
        if factor_dtype in ("fp8_e4m3", "fp8_e5m2"):
            return factor_dtype[4:]
        raise ValueError(f"unknown factor_dtype {factor_dtype!r}; expected "
                         f"'fp8_e4m3' | 'fp8_e5m2' or a jnp dtype")
    return None


def compute_scale(amax: jax.Array, fmt: str,
                  scale_mode: str = "fp32") -> jax.Array:
    """Per-tile scale mapping |x| <= amax onto the format's finite range."""
    if fmt not in FMT_MAX:
        raise ValueError(f"unknown fp8 format {fmt!r}; expected "
                         f"{sorted(FMT_MAX)}")
    s = amax.astype(jnp.float32) * FMT_INV_MAX[fmt]
    if scale_mode == "pow2":
        s = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(s, 2.0 ** -126))))
    elif scale_mode != "fp32":
        raise ValueError(f"unknown scale_mode {scale_mode!r}; "
                         f"expected 'fp32' | 'pow2'")
    return jnp.where(amax > 0, s, 1.0).astype(jnp.float32)


def quantize_rows(x: jax.Array, fmt: str = "e4m3",
                  scale_mode: str = "fp32") -> tuple[jax.Array, jax.Array]:
    """(..., t) -> (payload fp8 (..., t), scale f32 (...,)); one scale per
    trailing row. This is the reference implementation of the quantize half
    of the ``fp8_pack`` dispatch op."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = compute_scale(amax, fmt, scale_mode)
    m = FMT_MAX[fmt]
    q = jnp.clip(x / scale[..., None], -m, m)
    return q.astype(FORMATS[fmt]), scale


def dequantize_rows(payload: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_rows` up to fp8 rounding; returns f32."""
    return payload.astype(jnp.float32) * scale[..., None]


# ---------------------------------------------------------------------------
# Stat-level encode/decode (the optimizer-facing API)
# ---------------------------------------------------------------------------

def encode_stat(x: jax.Array, fmt: str, *, symmetric: Optional[bool] = None,
                scale_mode: str = "fp32",
                backend: Optional[str] = None) -> dict:
    """Encode one statistic to ``{"payload": fp8, "scale": f32}``.

    ``symmetric=True`` sym-packs the trailing (b, b) axes first (blocked
    factor layout); default sniffs square trailing axes. Callers that know
    the stat kind (the optimizer does) should pass it explicitly — a diag
    stat whose leading axis happens to equal its last would mis-sniff.
    """
    if symmetric is None:
        symmetric = x.ndim >= 2 and x.shape[-1] == x.shape[-2]
    if symmetric:
        from repro.kernels import dispatch
        payload, scale = dispatch.fp8_pack(x, fmt=fmt, scale_mode=scale_mode,
                                           backend=backend)
    else:
        payload, scale = quantize_rows(x, fmt, scale_mode)
    return {"payload": payload, "scale": scale}


def decode_stat(entry: dict, shape: tuple, *,
                symmetric: Optional[bool] = None,
                backend: Optional[str] = None) -> jax.Array:
    """Dequantize-on-read: encoded dict -> dense f32 of ``shape``."""
    if symmetric is None:
        symmetric = len(shape) >= 2 and shape[-1] == shape[-2]
    if symmetric:
        from repro.kernels import dispatch
        return dispatch.fp8_unpack(entry["payload"], entry["scale"],
                                   shape[-1], backend=backend)
    return dequantize_rows(entry["payload"], entry["scale"])


def is_wire(x: Any) -> bool:
    """Whether ``x`` is a wire-format stat: the ``{"payload", "scale"}``
    dict produced by the fused SYRK epilogue (``factor_sum_wire``) / by
    :func:`quantize_rows` — fp8 sym-packed rows + per-block f32 scales."""
    return isinstance(x, dict) and "payload" in x and "scale" in x


def tri_rows(t: int) -> int:
    """Inverse of the triangle count: ``t = b(b+1)/2 -> b``."""
    import math
    b = (math.isqrt(8 * t + 1) - 1) // 2
    if b * (b + 1) // 2 != t:
        raise ValueError(f"{t} is not a triangular number (not a sym-packed "
                         "row length)")
    return b


def wire_dense_shape(entry: dict) -> tuple:
    """Dense f32 shape a wire-format stat decodes to:
    payload (lead..., nb, t) -> (lead..., nb, b, b)."""
    p = entry["payload"]
    b = tri_rows(p.shape[-1])
    return tuple(p.shape[:-1]) + (b, b)


def decode_wire_stat(entry: dict) -> jax.Array:
    """Wire-format stat -> dense symmetric f32 blocks (one dequant, the
    jit-schedule counterpart of the reducer's post-collective decode)."""
    b = tri_rows(entry["payload"].shape[-1])
    from repro.core import kfac
    return kfac.sym_unpack(dequantize_rows(entry["payload"], entry["scale"]),
                           b)


def encoded_nbytes(shape: tuple, symmetric: Optional[bool] = None) -> int:
    """Resident bytes of the encoded form of a stat of ``shape``
    (fp8 payload + f32 per-block scales; sym-packed when symmetric)."""
    if symmetric is None:
        symmetric = len(shape) >= 2 and shape[-1] == shape[-2]
    if symmetric:
        b = shape[-1]
        blocks = int(np.prod(shape[:-2], dtype=np.int64))
        return blocks * (b * (b + 1) // 2) * PAYLOAD_BYTES \
            + blocks * SCALE_BYTES
    n = int(np.prod(shape, dtype=np.int64))
    rows = int(np.prod(shape[:-1], dtype=np.int64)) if len(shape) > 1 else 1
    return n * PAYLOAD_BYTES + rows * SCALE_BYTES
