from repro.quant.quant import (FORMATS, FMT_MAX, FACTOR_DTYPES,
                               PAYLOAD_BYTES, SCALE_BYTES,
                               parse_factor_dtype, compute_scale,
                               quantize_rows, dequantize_rows,
                               encode_stat, decode_stat, encoded_nbytes,
                               is_wire, tri_rows, wire_dense_shape,
                               decode_wire_stat)

__all__ = ["FORMATS", "FMT_MAX", "FACTOR_DTYPES", "PAYLOAD_BYTES",
           "SCALE_BYTES", "parse_factor_dtype", "compute_scale",
           "quantize_rows", "dequantize_rows",
           "encode_stat", "decode_stat", "encoded_nbytes",
           "is_wire", "tri_rows", "wire_dense_shape", "decode_wire_stat"]
