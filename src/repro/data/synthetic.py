"""Synthetic data pipelines.

No external datasets ship with this container, so training examples use
synthetic-but-learnable streams: a Zipf-distributed Markov token source for
LMs (so that next-token prediction has actual structure to learn) and a
separable Gaussian-mixture image source for the conv path.
"""

from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _markov_table(vocab: int, seed: int, branch: int = 8) -> np.ndarray:
    """Sparse row-stochastic transition table with Zipf marginals."""
    rng = np.random.RandomState(seed)
    nexts = rng.randint(0, vocab, size=(vocab, branch))
    probs = rng.dirichlet(np.ones(branch) * 0.5, size=vocab)
    return nexts, probs


def lm_batch(rng: np.random.RandomState, nexts, probs, batch: int,
             seq_len: int) -> dict:
    """One next-token-prediction batch from the Markov source."""
    vocab, branch = nexts.shape
    toks = np.empty((batch, seq_len + 1), np.int32)
    toks[:, 0] = rng.randint(0, vocab, size=batch)
    for t in range(seq_len):
        choice = np.array([rng.choice(branch, p=probs[tok])
                           for tok in toks[:, t]])
        toks[:, t + 1] = nexts[toks[:, t], choice]
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def token_batches(vocab: int, batch: int, seq_len: int, *,
                  seed: int = 0) -> Iterator[dict]:
    """Infinite LM batch iterator."""
    nexts, probs = _markov_table(vocab, seed)
    rng = np.random.RandomState(seed + 1)
    while True:
        yield lm_batch(rng, nexts, probs, batch, seq_len)


def image_batches(n_classes: int, batch: int, size: int = 32,
                  channels: int = 3, *, seed: int = 0) -> Iterator[dict]:
    """Gaussian-mixture images: class-dependent low-frequency pattern +
    noise. Learnable by a small ConvNet within a few hundred steps."""
    rng = np.random.RandomState(seed)
    protos = rng.randn(n_classes, size, size, channels).astype(np.float32)
    # low-pass the prototypes so convs with small kernels can pick them up
    for _ in range(3):
        protos = (protos + np.roll(protos, 1, 1) + np.roll(protos, 1, 2)) / 3
    while True:
        labels = rng.randint(0, n_classes, size=batch)
        imgs = protos[labels] + 0.5 * rng.randn(batch, size, size,
                                                channels).astype(np.float32)
        yield {"images": jnp.asarray(imgs), "labels": jnp.asarray(labels)}
