"""Data augmentation from paper §6.1: running mixup + random erasing."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class RunningMixup:
    """Paper Eq. 18-19: virtual samples are mixed from the *previous step's
    virtual samples*, not just raw samples (stronger regularization than
    vanilla mixup).

        x~(t) = lam * x(t) + (1 - lam) * x~(t-1)
        t~(t) = lam * t(t) + (1 - lam) * t~(t-1)

    lam ~ Beta(alpha, alpha). Labels must be soft (one-hot / prob vectors).
    """

    def __init__(self, alpha: float, n_classes: int, seed: int = 0):
        self.alpha = alpha
        self.n_classes = n_classes
        self.rng = np.random.RandomState(seed)
        self.prev_x: Optional[jnp.ndarray] = None
        self.prev_t: Optional[jnp.ndarray] = None

    def __call__(self, images: jax.Array, labels: jax.Array) -> tuple:
        soft = jax.nn.one_hot(labels, self.n_classes) \
            if labels.ndim == 1 else labels
        if self.prev_x is None:
            self.prev_x, self.prev_t = images, soft
            return images, soft
        lam = float(self.rng.beta(self.alpha, self.alpha))
        x = lam * images + (1 - lam) * self.prev_x
        t = lam * soft + (1 - lam) * self.prev_t
        self.prev_x, self.prev_t = x, t
        return x, t


def random_erase(rng: np.random.RandomState, images: np.ndarray, *,
                 p: float = 0.5, area: tuple = (0.02, 0.25),
                 aspect: tuple = (0.3, 1.0)) -> np.ndarray:
    """Paper §6.1 Random Erasing *with zero value* (not random values);
    erasing aspect ratio randomly switched (He, We) <-> (We, He)."""
    out = np.array(images)
    b, h, w, _ = out.shape
    for i in range(b):
        if rng.rand() >= p:
            continue
        se = rng.uniform(*area) * h * w
        re = rng.uniform(*aspect)
        he = int(round(np.sqrt(se * re)))
        we = int(round(np.sqrt(se / re)))
        if rng.rand() < 0.5:
            he, we = we, he
        he, we = min(he, h), min(we, w)
        if he < 1 or we < 1:
            continue
        y0 = rng.randint(0, h - he + 1)
        x0 = rng.randint(0, w - we + 1)
        out[i, y0:y0 + he, x0:x0 + we, :] = 0.0
    return out
