from repro.data.synthetic import token_batches, lm_batch
from repro.data.augment import RunningMixup, random_erase
