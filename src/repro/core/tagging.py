"""Layer tagging: curvature capture fused into the regular backward pass.

The paper's "practical" pillar (§4.1) is that the *empirical* Fisher can be
estimated during the ordinary forward/backward pass, with no extra
Monte-Carlo backward. We realize that in JAX with a *dummy-cotangent* trick:

Every tagged site (dense matmul, conv-as-im2col matmul, grouped/MoE matmul,
scale-bias, embedding) is a ``jax.custom_vjp`` whose primal takes extra
all-zero "statistics accumulator" arguments. The forward ignores them; the
backward returns, as their cotangents, the *raw factor sums*

    d(a_acc) = sum_t a_t a_t^T     (blocked, f32)
    d(g_acc) = sum_t gy_t gy_t^T   (blocked, f32; gy = dL/ds, un-normalized)

so ``jax.grad`` over (params, fstats) yields the gradients *and* the factor
statistics in one backward pass. Under ``lax.scan`` over layers the dummies
ride along as per-layer ``xs`` and their cotangents stack to (L, ...) —
giving the uniform "factor family" arrays of DESIGN.md §2 for free.

Normalization (tokens vs samples, mean-loss scaling) is deliberately NOT done
here — sites return raw sums; ``core/fisher.py`` normalizes with global
counts (which under pjit are the *global* batch, under shard_map the local
one plus a psum).

When a site's stats argument is ``None`` the plain op runs (zero overhead) —
this is the "no refresh this step" fast path of Algorithm 1.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import kfac


# ---------------------------------------------------------------------------
# Factor spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FactorSpec:
    """Static description of what curvature a site collects.

    ``a_max``/``g_max`` override ``max_dim`` per side — used to align factor
    blocks to tensor-parallel shard boundaries so block construction never
    crosses shards (zero cross-shard factor communication; DESIGN.md §4).

    ``backend`` selects the factor-construction kernel for this site
    ("ref" | "pallas" | "auto"; :mod:`repro.kernels.dispatch`).

    ``wire_fmt`` ("" | "e4m3" | "e5m2") switches FULL-kind factor capture to
    the fused wire format: the site's accumulator (and its cotangent) become
    ``{"payload": fp8 (lead..., nb, t), "scale": f32 (lead..., nb)}`` dicts
    emitted by ``factor_sum_wire`` — sym-packed + per-block-quantized inside
    the SYRK epilogue, so the raw f32 sum never round-trips HBM before the
    Stage-3 collective (the "fused" comm strategy consumes these directly).
    Diag / unit-wise stats are unaffected.
    """
    a_kind: str = "full"        # "full" | "diag" | "none"
    g_kind: str = "full"        # "full" | "diag" | "none"
    max_dim: int = 2048         # block-diagonal factor cap (DESIGN.md §4)
    a_max: int = 0              # 0 -> max_dim
    g_max: int = 0
    backend: str = "auto"       # kernel backend for this site's factor sums
    wire_fmt: str = ""          # "" (dense f32) | "e4m3" | "e5m2"
    wire_scale_mode: str = "fp32"  # per-block scale mode for wire capture

    @property
    def a_dim(self) -> int:
        return self.a_max or self.max_dim

    @property
    def g_dim(self) -> int:
        return self.g_max or self.max_dim

    def a_shape(self, d_in: int) -> Optional[tuple[int, ...]]:
        if self.a_kind == "full":
            nb = kfac.num_blocks(d_in, self.a_dim)
            b = kfac.block_size(d_in, self.a_dim)
            return (nb, b, b)
        if self.a_kind == "diag":
            return (d_in,)
        return None

    def g_shape(self, d_out: int) -> Optional[tuple[int, ...]]:
        if self.g_kind == "full":
            nb = kfac.num_blocks(d_out, self.g_dim)
            b = kfac.block_size(d_out, self.g_dim)
            return (nb, b, b)
        if self.g_kind == "diag":
            return (d_out,)
        return None


def _wire_zeros(spec: FactorSpec, shape: tuple[int, ...],
                lead: tuple[int, ...]) -> dict:
    """Zero wire-format accumulator for one full-kind factor of dense shape
    ``(nb, b, b)``: fp8 payload rows + per-block f32 scales."""
    from repro import quant
    if spec.wire_fmt not in quant.FORMATS:
        raise ValueError(f"unknown wire_fmt {spec.wire_fmt!r}; expected "
                         f"{sorted(quant.FORMATS)}")
    nb, b = shape[0], shape[-1]
    t = b * (b + 1) // 2
    return {"payload": jnp.zeros(lead + (nb, t),
                                 quant.FORMATS[spec.wire_fmt]),
            "scale": jnp.zeros(lead + (nb,), jnp.float32)}


def make_stats(spec: FactorSpec, d_in: int, d_out: int,
               lead: tuple[int, ...] = ()) -> dict:
    """Zero stats-accumulator pytree for one site ("fstats" leaf)."""
    out = {}
    sa = spec.a_shape(d_in)
    sg = spec.g_shape(d_out)
    if sa is not None:
        out["a"] = (_wire_zeros(spec, sa, lead)
                    if spec.wire_fmt and spec.a_kind == "full"
                    else jnp.zeros(lead + sa, jnp.float32))
    if sg is not None:
        out["g"] = (_wire_zeros(spec, sg, lead)
                    if spec.wire_fmt and spec.g_kind == "full"
                    else jnp.zeros(lead + sg, jnp.float32))
    return out


def _acc_shape(acc):
    """Residual-friendly shape of one accumulator: a plain tuple, or a
    {"payload", "scale"} dict of tuples for wire-format capture."""
    if isinstance(acc, dict):
        return {k: v.shape for k, v in acc.items()}
    return acc.shape


def _stat_sum(x2d: jax.Array, kind: str, max_dim: int,
              want_shape, backend: str = "auto",
              spec: Optional[FactorSpec] = None):
    """Raw factor sum for a token matrix (n, d), matching the dummy's shape
    (which may include leading group axes already consumed by the caller).
    A dict ``want_shape`` requests wire-format capture: the fused
    ``factor_sum_wire`` op returns the sym-packed fp8 payload + per-block
    scales as the cotangent (kind is necessarily "full")."""
    if isinstance(want_shape, dict):
        payload, scale = kfac.factor_sum_wire(
            x2d, max_dim, fmt=spec.wire_fmt,
            scale_mode=spec.wire_scale_mode, backend=backend)
        return {"payload": payload.reshape(want_shape["payload"]),
                "scale": scale.reshape(want_shape["scale"])}
    if kind == "full":
        return kfac.factor_sum(x2d, max_dim,
                               backend=backend).reshape(want_shape)
    if kind == "diag":
        return kfac.diag_factor_sum(x2d).reshape(want_shape)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Dense site: y = x @ w      x: (..., d_in), w: (d_in, d_out)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dense_site(spec: FactorSpec, x, w, a_acc, g_acc):
    return jnp.matmul(x, w)


def _dense_site_fwd(spec, x, w, a_acc, g_acc):
    y = jnp.matmul(x, w)
    return y, (x, w, _acc_shape(a_acc), _acc_shape(g_acc))


def _dense_site_bwd(spec, res, gy):
    x, w, a_shape, g_shape = res
    d_in, d_out = w.shape
    x2d = x.reshape(-1, d_in)
    g2d = gy.reshape(-1, d_out)
    dw = jnp.matmul(x2d.T, g2d.astype(x2d.dtype)).astype(w.dtype)
    dx = jnp.matmul(gy, w.T).astype(x.dtype)
    da = (_stat_sum(x2d, spec.a_kind, spec.a_dim, a_shape, spec.backend,
                    spec)
          if a_shape else jnp.zeros(a_shape))
    dg = (_stat_sum(g2d, spec.g_kind, spec.g_dim, g_shape, spec.backend,
                    spec)
          if g_shape else jnp.zeros(g_shape))
    return dx, dw, da, dg


_dense_site.defvjp(_dense_site_fwd, _dense_site_bwd)


def dense_site(x: jax.Array, w: jax.Array, stats: Optional[dict],
               spec: FactorSpec = FactorSpec()) -> jax.Array:
    """Tagged dense matmul. ``stats`` is the zero-accumulator dict from
    :func:`make_stats` (or None for the untagged fast path)."""
    if stats is None:
        return jnp.matmul(x, w)
    zero = jnp.zeros((), jnp.float32)
    return _dense_site(spec, x, w, stats.get("a", zero), stats.get("g", zero))


# ---------------------------------------------------------------------------
# Grouped dense site (MoE experts): y[e] = x[e] @ w[e]
#   x: (E, n, d_in), w: (E, d_in, d_out) -> per-expert factors (E, nb, b, b)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grouped_site(spec: FactorSpec, x, w, a_acc, g_acc):
    return jnp.einsum("end,edf->enf", x, w)


def _grouped_site_fwd(spec, x, w, a_acc, g_acc):
    return jnp.einsum("end,edf->enf", x, w), (x, w, _acc_shape(a_acc),
                                              _acc_shape(g_acc))


def _grouped_site_bwd(spec, res, gy):
    x, w, a_shape, g_shape = res
    dw = jnp.einsum("end,enf->edf", x, gy.astype(x.dtype)).astype(w.dtype)
    dx = jnp.einsum("enf,edf->end", gy, w).astype(x.dtype)
    # factor sums keep the expert axis: (E, n, d) -> (E, nb, b, b)
    da = (_stat_sum(x, spec.a_kind, spec.a_dim, a_shape, spec.backend, spec)
          if a_shape else None)
    dg = (_stat_sum(gy, spec.g_kind, spec.g_dim, g_shape, spec.backend, spec)
          if g_shape else None)
    if da is None:
        da = jnp.zeros(a_shape)
    if dg is None:
        dg = jnp.zeros(g_shape)
    return dx, dw, da, dg


_grouped_site.defvjp(_grouped_site_fwd, _grouped_site_bwd)


def grouped_dense_site(x: jax.Array, w: jax.Array, stats: Optional[dict],
                       spec: FactorSpec = FactorSpec()) -> jax.Array:
    if stats is None:
        return jnp.einsum("end,edf->enf", x, w)
    zero = jnp.zeros((), jnp.float32)
    return _grouped_site(spec, x, w, stats.get("a", zero), stats.get("g", zero))


# ---------------------------------------------------------------------------
# Bias site: y = x + b  (diagonal Fisher for b; paper treats biases unit-wise)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _bias_site(x, b, acc):
    return x + b


def _bias_site_fwd(x, b, acc):
    return x + b, (b.shape,)


def _bias_site_bwd(res, gy):
    (b_shape,) = res
    g2d = gy.reshape(-1, b_shape[-1]).astype(jnp.float32)
    db = g2d.sum(0).astype(jnp.float32)
    dacc = jnp.sum(g2d * g2d, axis=0)
    return gy, db, dacc


_bias_site.defvjp(_bias_site_fwd, _bias_site_bwd)


def bias_site(x: jax.Array, b: jax.Array, stats: Optional[dict]) -> jax.Array:
    if stats is None:
        return x + b
    return _bias_site(x, b, stats["d"])


def make_bias_stats(d: int, lead: tuple[int, ...] = ()) -> dict:
    return {"d": jnp.zeros(lead + (d,), jnp.float32)}


# ---------------------------------------------------------------------------
# Scale-bias site (BatchNorm / RMSNorm affine): y = xhat * gamma (+ beta)
# Unit-wise 2x2 Fisher (Eq. 15-16). ``spatial`` counts trailing token axes
# *within one sample* to sum over before the outer product (conv: H, W).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _scale_bias_site(spatial: int, has_beta: bool, xhat, gamma, beta, acc):
    y = xhat * gamma
    return y + beta if has_beta else y


def _scale_bias_site_fwd(spatial, has_beta, xhat, gamma, beta, acc):
    y = xhat * gamma
    if has_beta:
        y = y + beta
    return y, (xhat, gamma, acc.shape)


def _scale_bias_site_bwd(spatial, has_beta, res, gy):
    xhat, gamma, acc_shape = res
    c = xhat.shape[-1]
    gf = gy.astype(jnp.float32)
    xf = xhat.astype(jnp.float32)
    u = gf * xf                                   # per-position dL/dgamma
    # per-sample grads: sum the ``spatial`` axes right before the channel axis
    if spatial:
        ax = tuple(range(-1 - spatial, -1))
        us = u.sum(ax)
        vs = gf.sum(ax)
    else:
        us, vs = u, gf
    us2 = us.reshape(-1, c)
    vs2 = vs.reshape(-1, c)
    dgamma = us2.sum(0)
    dbeta = vs2.sum(0)
    if len(acc_shape) >= 2 and acc_shape[-1] == 2 * c:
        # FULL BN Fisher (2C x 2C) — the paper's expensive baseline (Fig. 5
        # "fullBN"): outer products of the concatenated per-sample grads.
        z = jnp.concatenate([us2, vs2], axis=-1)  # (n, 2C)
        dacc = (z.T @ z).reshape(acc_shape)
    else:
        # unit-wise stats (C, 3): [sum u^2, sum u v, sum v^2] (Eq. 15-16)
        dacc = jnp.stack([jnp.sum(us2 * us2, 0),
                          jnp.sum(us2 * vs2, 0),
                          jnp.sum(vs2 * vs2, 0)], axis=-1).reshape(acc_shape)
    dx = (gf * gamma).astype(xhat.dtype)
    if not has_beta:
        dbeta = jnp.zeros_like(dbeta)
    return dx, dgamma.astype(gamma.dtype), dbeta.astype(gamma.dtype), dacc


_scale_bias_site.defvjp(_scale_bias_site_fwd, _scale_bias_site_bwd)


def scale_bias_site(xhat: jax.Array, gamma: jax.Array,
                    beta: Optional[jax.Array], stats: Optional[dict],
                    spatial: int = 0) -> jax.Array:
    if stats is None:
        y = xhat * gamma
        return y + beta if beta is not None else y
    has_beta = beta is not None
    b = beta if has_beta else jnp.zeros_like(gamma)
    acc = stats["uwf"] if "uwf" in stats else stats["uw"]
    return _scale_bias_site(spatial, has_beta, xhat, gamma, b, acc)


def make_scale_bias_stats(c: int, lead: tuple[int, ...] = (),
                          full: bool = False) -> dict:
    if full:
        return {"uwf": jnp.zeros(lead + (2 * c, 2 * c), jnp.float32)}
    return {"uw": jnp.zeros(lead + (c, 3), jnp.float32)}


# ---------------------------------------------------------------------------
# Embedding site: y = table[ids]
#   A factor = diag(token counts); G factor = blocked gy^T gy over tokens.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _embed_site(spec: FactorSpec, ids, table, a_acc, g_acc):
    return jnp.take(table, ids, axis=0)


def _embed_site_fwd(spec, ids, table, a_acc, g_acc):
    return jnp.take(table, ids, axis=0), (ids, table.shape, _acc_shape(a_acc),
                                          _acc_shape(g_acc))


def _embed_site_bwd(spec, res, gy):
    ids, tshape, a_shape, g_shape = res
    v, d = tshape
    flat_ids = ids.reshape(-1)
    g2d = gy.reshape(-1, d)
    dtable = jnp.zeros(tshape, gy.dtype).at[flat_ids].add(g2d)
    da = jnp.zeros(a_shape, jnp.float32).at[flat_ids].add(1.0) if a_shape else jnp.zeros(a_shape)
    dg = (_stat_sum(g2d, spec.g_kind, spec.g_dim, g_shape, spec.backend,
                    spec)
          if g_shape else jnp.zeros(g_shape))
    dids = np.zeros(ids.shape, dtype=jax.dtypes.float0)  # int input: no tangent
    return dids, dtable, da, dg


_embed_site.defvjp(_embed_site_fwd, _embed_site_bwd)


def embed_site(ids: jax.Array, table: jax.Array, stats: Optional[dict],
               spec: FactorSpec = FactorSpec(a_kind="diag")) -> jax.Array:
    if stats is None:
        return jnp.take(table, ids, axis=0)
    zero = jnp.zeros((), jnp.float32)
    return _embed_site(spec, ids, table, stats.get("a", zero), stats.get("g", zero))


def make_embed_stats(vocab: int, d: int, spec: FactorSpec,
                     lead: tuple[int, ...] = ()) -> dict:
    out = {"a": jnp.zeros(lead + (vocab,), jnp.float32)}
    sg = spec.g_shape(d)
    if sg is not None:
        out["g"] = jnp.zeros(lead + sg, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Conv site = im2col patches + dense_site (paper Eq. 10-11): the Kronecker
# factors of a conv layer are exactly the dense factors of its im2col matmul.
# ---------------------------------------------------------------------------

def conv_site(x: jax.Array, w: jax.Array, stats: Optional[dict],
              stride: int = 1, padding: str = "SAME",
              spec: FactorSpec = FactorSpec()) -> jax.Array:
    """2D conv, NHWC, w: (kh, kw, cin, cout), via im2col + tagged matmul."""
    kh, kw, cin, cout = w.shape
    if stats is None and (kh, kw) == (1, 1) and stride == 1:
        return jnp.einsum("bhwc,cd->bhwd", x, w[0, 0])
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # conv_general_dilated_patches returns channels ordered (cin, kh, kw) in
    # the feature dim; reorder w to match: (cin, kh, kw, cout).
    w2d = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    return dense_site(patches, w2d, stats, spec)
