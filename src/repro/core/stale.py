"""Stale statistics with adaptive refresh intervals (paper §4.3, Alg. 1-2).

Host-side controller: per *statistic* (each factor family's "a", "g", "d",
"uw" array is one statistic X), track

    t_X       next step at which X must be refreshed
    delta     current acceptable interval
    delta_m1  previous interval

Algorithm 2, driven by Frobenius similarity measured on-device at refresh
time (``sim1 = ||X - X_-1||_F/||X_-1||_F``, ``sim2`` vs ``X_-2``). The
recurrence is over interval *generations* (§4.3): ``delta`` is the interval
that just elapsed, ``delta_m1`` (the paper's Δ₋₁) the one before it — the
last interval that was validated before the current (tentative) growth step:

    if   sim1 >= alpha:  delta <- max(1, floor(delta_m1 / 2))   # shrink
    elif sim2 >= alpha:  delta <- delta_m1                      # fall back
    else:                delta <- delta + delta_m1              # Fibonacci grow

Shrink/fall-back restart from Δ₋₁ (the just-elapsed Δ was too aggressive);
growth extends the streak, giving the Fibonacci sequence 1, 1, 2, 3, 5, …
when X keeps drifting slowly.

The device side stores X_-1 / X_-2 inside the optimizer state and evaluates
the two distances only on refresh steps (inside the ``lax.cond``); the
controller consumes them after the step and schedules the next refresh.

The controller also keeps the byte/flop ledger used by the paper's Table 2 /
Fig. 6 communication-reduction benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class StatState:
    t_next: int = 1          # Algorithm 1: t_X <- 1 initially
    delta: int = 1
    delta_m1: int = 1
    bytes_per_refresh: int = 0   # symmetric-packed storage payload
    wire_bytes_per_refresh: int = 0  # Stage-3 collective payload (the
                                     # actual wire dtype; repro.comm)
    # per-level split of the wire payload under the hierarchical ("hier")
    # strategy: intra-host full-precision scatter vs inter-host fp8 ring.
    # Both stay 0 under flat strategies (the split is then meaningless).
    wire_intra_bytes_per_refresh: int = 0
    wire_inter_bytes_per_refresh: int = 0
    # Stage-4 return leg under sharded inversion: the preconditioner
    # all-gather (sym-packed f32; repro.comm.gather_stat_bytes). 0 for
    # replicated inversion and for statistics that never shard.
    gather_bytes_per_refresh: int = 0
    refresh_count: int = 0


class IntervalController:
    """Implements Algorithm 1's bookkeeping + Algorithm 2's interval rule."""

    def __init__(self, stat_names: list[str], alpha: float = 0.1,
                 max_interval: int = 0, min_interval: int = 1,
                 bytes_per_stat: Optional[dict[str, int]] = None,
                 wire_bytes_per_stat: Optional[dict[str, int]] = None,
                 wire_level_bytes_per_stat: Optional[dict] = None,
                 gather_bytes_per_stat: Optional[dict[str, int]] = None):
        self.alpha = alpha
        self.max_interval = max_interval          # 0 = unbounded (paper)
        # Floor on Algorithm 2's shrink: with the chunked refresh pipeline
        # (repro.core.pipeline) a refresh stays in flight for K chunk steps
        # plus the activation step after its capture, so the controller must
        # not schedule the next capture before the drain completes —
        # train.py passes refresh_chunks + 1. The default (1) is the paper's
        # unconstrained rule and leaves the Fibonacci recurrence untouched.
        self.min_interval = max(1, min_interval)
        self.stats = {n: StatState() for n in stat_names}
        if bytes_per_stat:
            for n, b in bytes_per_stat.items():
                self.stats[n].bytes_per_refresh = b
        if wire_bytes_per_stat:
            for n, b in wire_bytes_per_stat.items():
                self.stats[n].wire_bytes_per_refresh = b
        if wire_level_bytes_per_stat:
            # {name: (intra, inter)} — FactorReducer.wire_bytes_per_stat_levels
            for n, (intra, inter) in wire_level_bytes_per_stat.items():
                self.stats[n].wire_intra_bytes_per_refresh = intra
                self.stats[n].wire_inter_bytes_per_refresh = inter
        if gather_bytes_per_stat:
            # Stage-4 preconditioner gather under sharded inversion —
            # FactorReducer.gather_bytes_per_stat / SPNGD.gather_bytes
            for n, b in gather_bytes_per_stat.items():
                self.stats[n].gather_bytes_per_refresh = b
        self.total_bytes = 0
        self.dense_bytes = 0                      # what refresh-every-step would cost
        self.total_wire_bytes = 0
        self.dense_wire_bytes = 0
        self.total_wire_intra_bytes = 0
        self.dense_wire_intra_bytes = 0
        self.total_wire_inter_bytes = 0
        self.dense_wire_inter_bytes = 0
        self.total_gather_bytes = 0
        self.dense_gather_bytes = 0
        self.comm_info: dict = {}                 # reducer tally (record_comm)
        self.steps = 0
        # drain() snapshot: cumulative counter values already handed out, so
        # per-step JSONL deltas sum back to the totals exactly
        self._drained: dict[str, float] = {}

    def flags(self, t: int) -> dict[str, bool]:
        """Which statistics must refresh at step t (Algorithm 1's t == t_X)."""
        return {n: t >= s.t_next for n, s in self.stats.items()}

    def update(self, t: int, flags: dict[str, bool],
               sims: dict[str, tuple[float, float]]) -> None:
        """Feed back measured similarities after the step ran.

        sims[name] = (dist_to_prev, dist_to_prev2); entries for statistics
        that did not refresh are ignored.
        """
        self.steps += 1
        for name, st in self.stats.items():
            self.dense_bytes += st.bytes_per_refresh
            self.dense_wire_bytes += st.wire_bytes_per_refresh
            self.dense_wire_intra_bytes += st.wire_intra_bytes_per_refresh
            self.dense_wire_inter_bytes += st.wire_inter_bytes_per_refresh
            self.dense_gather_bytes += st.gather_bytes_per_refresh
            if not flags.get(name, False):
                continue
            d1, d2 = sims[name]
            # Algorithm 2: shrink/fall-back compute from the PREVIOUS
            # interval Δ₋₁ (st.delta_m1), not the just-elapsed st.delta —
            # growth is tentative until the similarity check validates it
            if d1 >= self.alpha:
                delta = max(1, st.delta_m1 // 2)
            elif d2 >= self.alpha:
                delta = st.delta_m1
            else:
                delta = st.delta + st.delta_m1
            delta = max(delta, self.min_interval)
            if self.max_interval:
                delta = min(delta, self.max_interval)
            st.delta_m1 = st.delta
            st.delta = delta
            st.t_next = t + delta
            st.refresh_count += 1
            self.total_bytes += st.bytes_per_refresh
            self.total_wire_bytes += st.wire_bytes_per_refresh
            self.total_wire_intra_bytes += st.wire_intra_bytes_per_refresh
            self.total_wire_inter_bytes += st.wire_inter_bytes_per_refresh
            self.total_gather_bytes += st.gather_bytes_per_refresh

    # ---- Stage-3 comm bookkeeping (repro.comm reducer tally) ----

    def record_comm(self, info: dict) -> None:
        """Attach the reducer's scatter report (strategy, wire dtype,
        replication-fallback tally — ``FactorReducer.scatter_report()``) so
        :meth:`summary` surfaces which statistics never scattered."""
        self.comm_info.update(info)

    # ---- checkpoint continuity (Algorithm 1's intervals assume it) ----

    def state_dict(self) -> dict:
        """JSON-serializable controller state for checkpointing."""
        return {
            "alpha": self.alpha,
            "max_interval": self.max_interval,
            "min_interval": self.min_interval,
            "steps": self.steps,
            "total_bytes": self.total_bytes,
            "dense_bytes": self.dense_bytes,
            "total_wire_bytes": self.total_wire_bytes,
            "dense_wire_bytes": self.dense_wire_bytes,
            "total_wire_intra_bytes": self.total_wire_intra_bytes,
            "dense_wire_intra_bytes": self.dense_wire_intra_bytes,
            "total_wire_inter_bytes": self.total_wire_inter_bytes,
            "dense_wire_inter_bytes": self.dense_wire_inter_bytes,
            "total_gather_bytes": self.total_gather_bytes,
            "dense_gather_bytes": self.dense_gather_bytes,
            "comm_info": dict(self.comm_info),
            "drained": dict(self._drained),
            "stats": {n: dataclasses.asdict(s) for n, s in self.stats.items()},
        }

    @classmethod
    def from_state_dict(cls, state: dict) -> "IntervalController":
        # pre-PR-10 checkpoints have no pipeline floor: resume unconstrained
        ctrl = cls(list(state["stats"]), alpha=state["alpha"],
                   max_interval=state["max_interval"],
                   min_interval=state.get("min_interval", 1))
        ctrl.steps = state["steps"]
        ctrl.total_bytes = state["total_bytes"]
        ctrl.dense_bytes = state["dense_bytes"]
        # pre-PR-5 checkpoints have no wire ledger: resume at zero
        ctrl.total_wire_bytes = state.get("total_wire_bytes", 0)
        ctrl.dense_wire_bytes = state.get("dense_wire_bytes", 0)
        # pre-PR-6 checkpoints have no per-level (hier) ledger: resume at 0
        ctrl.total_wire_intra_bytes = state.get("total_wire_intra_bytes", 0)
        ctrl.dense_wire_intra_bytes = state.get("dense_wire_intra_bytes", 0)
        ctrl.total_wire_inter_bytes = state.get("total_wire_inter_bytes", 0)
        ctrl.dense_wire_inter_bytes = state.get("dense_wire_inter_bytes", 0)
        # pre-PR-7 checkpoints have no Stage-4 gather ledger: resume at zero
        ctrl.total_gather_bytes = state.get("total_gather_bytes", 0)
        ctrl.dense_gather_bytes = state.get("dense_gather_bytes", 0)
        ctrl.comm_info = dict(state.get("comm_info", {}))
        # pre-PR-8 checkpoints have no drain snapshot: next drain() re-emits
        # everything accumulated so far, which keeps the sum-of-drains ==
        # totals invariant across the resume
        ctrl._drained = dict(state.get("drained", {}))
        for n, s in state["stats"].items():
            ctrl.stats[n] = StatState(**s)
        return ctrl

    # ---- reporting (paper Table 2 "reduction", Fig. 6) ----

    def reduction_rate(self) -> float:
        """Communicated bytes as a fraction of refresh-every-step bytes."""
        if self.dense_bytes == 0:
            return 1.0
        return self.total_bytes / self.dense_bytes

    def summary(self) -> dict:
        wire_rate = (self.total_wire_bytes / self.dense_wire_bytes
                     if self.dense_wire_bytes else 1.0)
        return {
            "steps": self.steps,
            "total_stat_bytes": self.total_bytes,
            "dense_stat_bytes": self.dense_bytes,
            "reduction_rate": self.reduction_rate(),
            "comm": {
                "total_wire_bytes": self.total_wire_bytes,
                "dense_wire_bytes": self.dense_wire_bytes,
                "wire_reduction_rate": wire_rate,
                # hier per-level split; identically 0 under flat strategies
                "total_wire_intra_bytes": self.total_wire_intra_bytes,
                "dense_wire_intra_bytes": self.dense_wire_intra_bytes,
                "total_wire_inter_bytes": self.total_wire_inter_bytes,
                "dense_wire_inter_bytes": self.dense_wire_inter_bytes,
                # Stage-4 preconditioner gather (sharded inversion);
                # identically 0 under replicated Stage-4
                "total_gather_bytes": self.total_gather_bytes,
                "dense_gather_bytes": self.dense_gather_bytes,
                **self.comm_info,
            },
            "per_stat": {n: dataclasses.asdict(s) for n, s in self.stats.items()},
        }

    # ---- flat / streaming views (JSONL emission; repro.obs) ----

    def counters(self) -> dict[str, int]:
        """The cumulative integer counters, flat. Every value in
        :meth:`summary` that monotonically accumulates appears here under
        its summary name (per-level comm totals included), plus the derived
        ``refresh_events`` (sum of per-stat refresh counts)."""
        return {
            "steps": self.steps,
            "total_stat_bytes": self.total_bytes,
            "dense_stat_bytes": self.dense_bytes,
            "total_wire_bytes": self.total_wire_bytes,
            "dense_wire_bytes": self.dense_wire_bytes,
            "total_wire_intra_bytes": self.total_wire_intra_bytes,
            "dense_wire_intra_bytes": self.dense_wire_intra_bytes,
            "total_wire_inter_bytes": self.total_wire_inter_bytes,
            "dense_wire_inter_bytes": self.dense_wire_inter_bytes,
            "total_gather_bytes": self.total_gather_bytes,
            "dense_gather_bytes": self.dense_gather_bytes,
            "refresh_events": sum(s.refresh_count for s in self.stats.values()),
        }

    def summary_flat(self) -> dict:
        """:meth:`summary` flattened to one ``dict[str, int | float]`` for
        direct JSONL emission: the counters, both reduction rates, and any
        numeric reducer-tally entries. No nested values."""
        flat: dict = dict(self.counters())
        flat["reduction_rate"] = self.reduction_rate()
        flat["wire_reduction_rate"] = (
            self.total_wire_bytes / self.dense_wire_bytes
            if self.dense_wire_bytes else 1.0)
        for k, v in self.comm_info.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                flat[f"comm_{k}"] = v
        return flat

    def drain(self) -> dict[str, int]:
        """Deltas of :meth:`counters` since the previous drain. Summing every
        drained dict over a run reproduces the cumulative counters exactly —
        the per-step JSONL events are a lossless decomposition of the ledger
        (pinned by tests/test_obs.py)."""
        cur = self.counters()
        out = {k: v - self._drained.get(k, 0) for k, v in cur.items()}
        self._drained = cur
        return out


def sym_packed_bytes(shape: tuple, dtype_bytes: int = 4) -> int:
    """Bytes for one symmetric-packed factor array (paper §5.2): the last two
    axes (b, b) cost b(b+1)/2 each; leading axes multiply. Fixed element
    size; fp8 payload + per-block scale accounting lives in
    :func:`stat_payload_bytes` (via ``quant.encoded_nbytes``)."""
    if len(shape) >= 2 and shape[-1] == shape[-2]:
        b = shape[-1]
        lead = 1
        for s in shape[:-2]:
            lead *= s
        return lead * (b * (b + 1) // 2) * dtype_bytes
    n = 1
    for s in shape:
        n *= s
    return n * dtype_bytes


def stat_payload_bytes(shape: tuple, factor_dtype,
                       symmetric: Optional[bool] = None) -> int:
    """Sym-packed payload bytes for one statistic under the actual storage
    dtype: dense fp32/bf16 elements, or fp8 payload + per-block f32 scales
    (``factor_dtype`` in ``{"fp8_e4m3", "fp8_e5m2"}``; :mod:`repro.quant`).
    ``symmetric=False`` forces the non-packed (row-quantized) accounting for
    square-shaped stats that are not symmetric factors."""
    from repro import quant
    fmt = quant.parse_factor_dtype(factor_dtype)
    if symmetric is None:
        symmetric = len(shape) >= 2 and shape[-1] == shape[-2]
    if fmt is not None:
        return quant.encoded_nbytes(shape, symmetric=symmetric)
    import numpy as np
    dtype_bytes = int(np.dtype(factor_dtype).itemsize)
    if not symmetric:
        n = 1
        for s in shape:
            n *= s
        return n * dtype_bytes
    return sym_packed_bytes(shape, dtype_bytes)
