"""SP-NGD optimizer: the paper's update rule (Eq. 6/12/23/24) end to end.

Decoupled from any model class: the constructor takes

    loss_fn(params, fstats, batch) -> (loss, aux)
    site_infos: {family: SiteInfo}
    fstats_fn() -> zero statistics pytree (structure {family: {"a": ..., ...}})
    counts_fn(batch) -> {family: (n_a, n_g)}

Two jittable steps:

* ``step``      — full step with curvature capture; per-statistic refresh
                  flags gate the (communication + inversion) work via
                  ``lax.cond`` (Algorithm 1's skip).
* ``step_fast`` — no capture at all (every statistic within its interval):
                  a plain backward + stale-preconditioned update. This is the
                  path whose cost approaches SGD, the paper's headline claim.

The caller drives refresh scheduling with ``stale.IntervalController``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import kfac
from repro.core.fisher import SiteInfo, emp_fisher_grads, mc_fisher_grads, get_path, set_path


@dataclasses.dataclass(frozen=True)
class NGDConfig:
    damping: float = 2.5e-4          # paper Table 2 lambda
    stale: bool = True
    alpha: float = 0.1               # Frobenius similarity threshold
    estimator: str = "emp"           # "emp" | "1mc"
    inverse_method: str = "eigh"     # "eigh" | "cholesky" | "newton_schulz"
    ns_iters: int = kfac.NS_ITERS    # newton_schulz: iteration cap
    ns_tol: float = kfac.NS_TOL      # newton_schulz: relative fixed-point
                                     # residual for early exit; blocks still
                                     # above it at the cap re-solve via eigh
    factor_dtype: Any = jnp.float32  # storage dtype for X_-1/X_-2 history:
                                     # a jnp dtype (dense), or "fp8_e4m3" /
                                     # "fp8_e5m2" (sym-packed payload +
                                     # per-block scales; repro.quant)
    fp8_scale_mode: str = "fp32"     # "fp32" | "pow2" per-block scales
    weight_rescale: bool = False     # Eq. 24 (on for the conv/paper configs)
    rescale_eps: float = 1e-9
    history: int = 2                 # 2 = full Algorithm 2; 1 = cheap variant
    sgd_fallback_scale: float = 1.0  # lr scale for non-sited params
    backend: str = "auto"            # kernel backend for the hot paths
                                     # ("ref" | "pallas" | "auto";
                                     #  repro.kernels.dispatch)
    inverse_sharding: bool = False   # Stage-4 distribution: each device
                                     # inverts only its FactorReducer-owned
                                     # chunk of every full-kind factor and
                                     # the preconditioners all-gather
                                     # (repro.comm.stage4). Takes effect
                                     # under the shard_map schedule, which
                                     # attaches the Stage4Inverter; the jit
                                     # schedule ignores it (replicated).
    double_buffer: bool = False      # pipeline refreshes behind training
                                     # compute: inverses produced by the
                                     # refresh at step t are STAGED and
                                     # activate at t+1.., while step t
                                     # still consumes the previous buffer
                                     # (paper §5.2 overlap; the staleness
                                     # itself is still Algorithm 2's)
    inverse_info: bool = False       # surface per-block Stage-4 inversion
                                     # diagnostics (ns_res / ns_converged)
                                     # in step metrics["inverse_info"] —
                                     # blocks not refreshed this step carry
                                     # the ns_res=-1 sentinel (repro.obs
                                     # consumes this; off by default so the
                                     # metric tree is unchanged)
    refresh_chunks: int = 1          # chunked refresh pipeline
                                     # (repro.core.pipeline): >1 splits
                                     # every refresh's Stage-4 inversions
                                     # + gathers into this many chunks,
                                     # executed one per subsequent fast
                                     # step and activated atomically
                                     # K+1 steps after the capture.
                                     # Requires double_buffer; the
                                     # IntervalController must run with
                                     # min_interval = refresh_chunks + 1
                                     # so a drain finishes before the
                                     # next capture. 1 = inline refresh
                                     # (the pre-pipeline behaviour).


def _dense_leaf_shape(leaf) -> tuple:
    """Template-leaf shape in dense f32 terms: wire-format capture dicts
    (fused SYRK epilogue) report the shape their payload decodes to, so the
    optimizer's history / preconditioner state is capture-format invariant."""
    from repro import quant
    if quant.is_wire(leaf):
        return quant.wire_dense_shape(leaf)
    return tuple(leaf.shape)


def _mean_eig(stat: jax.Array, kind: str, d: int) -> jax.Array:
    """Average eigenvalue of a factor (full blocked or diagonal)."""
    if kind == "full":
        return jnp.trace(stat, axis1=-2, axis2=-1).sum(-1) / d
    return stat.sum(-1) / d


def _damped_inv(stat: jax.Array, kind: str, damp: jax.Array,
                method: str, backend: str = "auto",
                ns_iters: int = kfac.NS_ITERS,
                ns_tol: float = kfac.NS_TOL,
                return_info: bool = False):
    """Apply-ready inverse: blocked matrix inverse or elementwise 1/(x+d).

    ``return_info=True`` additionally returns the dispatch layer's
    per-block ``{"ns_res", "ns_converged"}`` for full-kind stats (None for
    the elementwise kinds, which have no fallback to report)."""
    if kind == "full":
        from repro.kernels import dispatch
        return dispatch.damped_inverse(stat, damp[..., None], method=method,
                                       ns_iters=ns_iters, ns_tol=ns_tol,
                                       backend=backend,
                                       return_info=return_info)  # bcast over blocks
    inv = 1.0 / (jnp.maximum(stat, 0.0) + damp[..., None])
    return (inv, None) if return_info else inv


class SPNGD:
    def __init__(self, loss_fn: Callable, site_infos: dict[str, SiteInfo],
                 fstats_fn: Callable, counts_fn: Callable,
                 cfg: NGDConfig = NGDConfig(),
                 sharding_hook: Optional[Callable] = None):
        """``sharding_hook(family, stat_key, array) -> array`` lets the launch
        layer pin factor arrays to the (data x model) mesh — this is where the
        paper's Stage-3 ReduceScatterV materializes under GSPMD (DESIGN §7)."""
        self.loss_fn = loss_fn
        self.infos = site_infos
        self.fstats_fn = fstats_fn
        self.counts_fn = counts_fn
        self.cfg = cfg
        self.sharding_hook = sharding_hook or (lambda fam, key, x: x)
        self.stage4 = None            # Stage4Inverter, set by the shard_map
                                      # step builder (set_stage4)
        from repro.quant import parse_factor_dtype
        self._fp8 = parse_factor_dtype(cfg.factor_dtype)  # fmt key or None
        self.pipeline = None          # RefreshPipeline when refresh_chunks>1
        if cfg.refresh_chunks > 1:
            if not cfg.double_buffer:
                raise ValueError("refresh_chunks > 1 needs double_buffer: "
                                 "the drain writes precond_next while the "
                                 "fast path consumes precond")
            if cfg.inverse_info:
                raise ValueError("inverse_info is unavailable with "
                                 "refresh_chunks > 1: the capture step "
                                 "runs no inversions to report on")
            from repro.core.pipeline import RefreshPipeline
            self.pipeline = RefreshPipeline(self, cfg.refresh_chunks)

    def set_stage4(self, inverter) -> None:
        """Attach (or detach, with None) a
        :class:`repro.comm.Stage4Inverter`: full-kind factor inverses then
        run shard-locally over the reducer's chunk layout and all-gather.
        The step builder calls this when ``cfg.inverse_sharding`` is on —
        the optimizer itself stays schedule-agnostic."""
        self.stage4 = inverter

    def sym_stat(self, fam: str, key: str) -> bool:
        """Whether a stat is a symmetric blocked factor (sym-packable) —
        shared by the fp8 history codec and the Stage-3 reducer
        (:class:`repro.comm.FactorReducer`), so packing decisions cannot
        drift between storage and wire."""
        if key in ("a", "g"):
            info = self.infos[fam]
            kind = info.spec.a_kind if key == "a" else info.spec.g_kind
            return kind == "full"
        return key == "uwf"                  # full BN Fisher is symmetric

    _sym_stat = sym_stat                     # pre-PR-5 spelling

    # ---- fp8 history codec (dequantize-on-read; repro.quant) ----

    def _encode_hist(self, fam: str, key: str, x: jax.Array):
        if self._fp8 is None:
            return x.astype(self.cfg.factor_dtype)
        from repro import quant
        return quant.encode_stat(x, self._fp8,
                                 symmetric=self.sym_stat(fam, key),
                                 scale_mode=self.cfg.fp8_scale_mode,
                                 backend=self.cfg.backend)

    def _decode_hist(self, fam: str, key: str, stored, shape) -> jax.Array:
        if self._fp8 is None:
            return stored.astype(jnp.float32)
        from repro import quant
        return quant.decode_stat(stored, shape,
                                 symmetric=self.sym_stat(fam, key),
                                 backend=self.cfg.backend)

    # ---- statistic naming for the interval controller ----

    def stat_names(self) -> list[str]:
        names = []
        template = jax.eval_shape(self.fstats_fn)
        for fam, stats in template.items():
            for key in stats:
                names.append(f"{fam}.{key}")
        return sorted(names)

    def stat_bytes(self, dtype_bytes: Optional[int] = None) -> dict[str, int]:
        """Symmetric-packed communication payload per statistic (§5.2).

        By default the payload dtype follows ``cfg.factor_dtype`` — fp32 /
        bf16 dense elements, or fp8 payload + per-block f32 scales — so the
        IntervalController's byte ledger reports what the Stage-3
        reduce-scatter would actually move. Pass ``dtype_bytes`` to force a
        fixed element size (e.g. 4 for an fp32-communication baseline)."""
        from repro.core.stale import stat_payload_bytes, sym_packed_bytes
        template = jax.eval_shape(self.fstats_fn)
        out = {}
        for fam, stats in template.items():
            for key, leaf in stats.items():
                shape = _dense_leaf_shape(leaf)
                if dtype_bytes is not None:
                    out[f"{fam}.{key}"] = sym_packed_bytes(shape,
                                                           dtype_bytes)
                else:
                    out[f"{fam}.{key}"] = stat_payload_bytes(
                        shape, self.cfg.factor_dtype,
                        symmetric=self.sym_stat(fam, key))
        return out

    def wire_bytes(self, comm=None, group_size=None) -> dict[str, int]:
        """Per-statistic Stage-3 collective payload under a
        :class:`repro.comm.CommConfig` — the wire-bytes column of the
        IntervalController ledger. Unlike :meth:`stat_bytes` (storage dtype)
        this reflects what the configured collective actually moves: dense
        f32 for ``dense``, sym-packed f32 for ``ring``, fp8 payload +
        per-block scales for ``ring_fp8``. Assumes the paper's layout where
        every statistic scatters; a mesh-specific reducer's
        ``wire_bytes_per_stat()`` additionally prices replication
        fallbacks at dense f32."""
        from repro import comm as comm_mod
        return comm_mod.template_wire_bytes(
            jax.eval_shape(self.fstats_fn), self.sym_stat,
            comm or comm_mod.CommConfig(), group_size=group_size)

    def gather_bytes(self) -> dict[str, int]:
        """Per-statistic Stage-4 preconditioner all-gather payload — the
        gather column of the IntervalController ledger when
        ``cfg.inverse_sharding`` distributes the inversions. Sym-packed f32
        triangles for the full-kind factors, 0 for everything else (only
        sharded inverses gather; the wire never quantizes). Mesh-less
        everything-scatters assumption, like :meth:`wire_bytes`; a
        mesh-specific reducer's ``gather_bytes_per_stat()`` additionally
        zeroes replication fallbacks."""
        from repro import comm as comm_mod
        return comm_mod.template_gather_bytes(
            jax.eval_shape(self.fstats_fn), self.sym_stat)

    def wire_level_bytes(self, comm=None,
                         group_size=None) -> dict[str, tuple[int, int]]:
        """Per-statistic (intra-host, inter-host) Stage-3 wire bytes — the
        ``hier`` level breakdown feeding the IntervalController's per-level
        ledger. Flat strategies report (0, 0) everywhere (same mesh-less
        everything-scatters assumption as :meth:`wire_bytes`).
        ``group_size`` models the scatter-group size for the hier split
        (default: this process's local device count)."""
        from repro import comm as comm_mod
        return comm_mod.template_wire_level_bytes(
            jax.eval_shape(self.fstats_fn), self.sym_stat,
            comm or comm_mod.CommConfig(), group_size=group_size)

    # ---- state ----

    def init(self, params) -> dict:
        template = jax.eval_shape(self.fstats_fn)
        curv = {}
        for fam, stats in template.items():
            info = self.infos[fam]
            entry = {"prev": {}, "prev2": {}, "precond": {}}
            for key, leaf in stats.items():
                shape = _dense_leaf_shape(leaf)
                z = self._encode_hist(fam, key,
                                      jnp.zeros(shape, jnp.float32))
                entry["prev"][key] = z
                if self.cfg.history >= 2:
                    entry["prev2"][key] = z
                if key in ("a", "g"):
                    kind = info.spec.a_kind if key == "a" else info.spec.g_kind
                    if kind == "full":
                        eye = jnp.broadcast_to(jnp.eye(shape[-1], dtype=jnp.float32),
                                               shape)
                        entry["precond"][key] = eye
                    else:
                        entry["precond"][key] = jnp.ones(shape, jnp.float32)
                else:                       # "d" (bias) / "uw" (2x2): store stats
                    entry["precond"][key] = jnp.zeros(shape, jnp.float32)
            if self.cfg.double_buffer:
                # staged buffer: what the NEXT step will activate. Seeding
                # it from the active init makes step 1 a plain identity-
                # preconditioned step (the pipeline's one-step warm-up).
                entry["precond_next"] = dict(entry["precond"])
            curv[fam] = entry
        state = {
            "step": jnp.zeros((), jnp.int32),
            "velocity": jax.tree.map(jnp.zeros_like, params),
            "curv": curv,
        }
        if self.pipeline is not None:
            state["pipeline"] = self.pipeline.init_state()
        return state

    # ---- curvature refresh (Algorithm 1's on-refresh work) ----

    def _shift_history(self, fam: str, raw: dict, curv: dict,
                       flags: dict, n_a, n_g):
        """Per-family pre-inversion refresh work: decode + normalize the raw
        sums, measure the Algorithm-2 similarities against history, and
        shift X₋₁/X₋₂ for the flagged statistics. Shared by the inline
        refresh (:meth:`_refresh_family`) and the pipeline's capture step
        (:meth:`_apply_capture`), which parks the normalized statistics and
        defers the inversions. Returns ``(normalized, new_prev, new_prev2,
        sims)`` — ``normalized[key]`` is the post-select view (the fresh
        statistic when flagged, the decoded X₋₁ otherwise)."""
        cfg = self.cfg
        new_prev, new_prev2, sims = {}, {}, {}
        normalized = {}
        for key, v in raw.items():
            from repro import quant
            if quant.is_wire(v):
                # fused wire capture under the plain-jit schedule: ONE
                # dequant here (the counterpart of the shard_map reducer's
                # post-collective decode) and the refresh math below is
                # byte-identical to the dense path
                v = quant.decode_wire_stat(v)
            norm = (v / n_a) if key == "a" else (v * n_g)
            norm = self.sharding_hook(fam, key, norm)
            flag = flags[f"{fam}.{key}"]
            # dequantize-on-read: fp8 history decodes to f32 here and only
            # here; Algorithm 2's similarity and the inverse recompute both
            # consume the decoded view
            prev = self._decode_hist(fam, key, curv["prev"][key], norm.shape)
            # similarity of the *fresh* statistic vs history (Algorithm 2 input)
            d1 = jnp.where(flag, kfac.frob_distance(norm, prev), -1.0)
            if cfg.history >= 2:
                prev2 = self._decode_hist(fam, key, curv["prev2"][key],
                                          norm.shape)
                d2 = jnp.where(flag, kfac.frob_distance(norm, prev2), -1.0)
            else:
                d2 = d1
            sims[f"{fam}.{key}"] = jnp.stack([d1, d2])
            # history shift happens only when refreshed
            x = jnp.where(flag, norm, prev)
            normalized[key] = x
            if self._fp8 is None:
                new_prev[key] = x.astype(cfg.factor_dtype)
                if cfg.history >= 2:
                    new_prev2[key] = jnp.where(flag, prev,
                                               prev2).astype(cfg.factor_dtype)
            else:
                # select at the ENCODED level: payload and scale shift
                # together, so an un-refreshed stat keeps its stored bits
                # (no re-quantization drift across skipped steps)
                enc = self._encode_hist(fam, key, norm)
                sel = lambda a, b: jax.tree.map(
                    functools.partial(jnp.where, flag), a, b)
                new_prev[key] = sel(enc, curv["prev"][key])
                if cfg.history >= 2:
                    new_prev2[key] = sel(curv["prev"][key],
                                         curv["prev2"][key])
        if cfg.history < 2:
            new_prev2 = curv["prev2"]
        return normalized, new_prev, new_prev2, sims

    def _refresh_family(self, fam: str, raw: dict, curv: dict,
                        flags: dict, lam, n_a, n_g):
        info = self.infos[fam]
        cfg = self.cfg
        normalized, new_prev, new_prev2, sims = self._shift_history(
            fam, raw, curv, flags, n_a, n_g)

        any_flag = functools.reduce(
            jnp.logical_or, [flags[f"{fam}.{k}"] for k in raw], jnp.asarray(False))

        # which stats carry per-block inversion diagnostics: the full-kind
        # a/g factors (static set — the cond's branch trees must match)
        want_info = cfg.inverse_info
        info_keys = [k for k in ("a", "g") if k in normalized and
                     (info.spec.a_kind if k == "a" else
                      info.spec.g_kind) == "full"] if want_info else []

        def recompute(_):
            from repro.obs.tracing import STAGE_INVERSE
            pc, inv_info = {}, {}
            if "a" in normalized or "g" in normalized:
                a = normalized.get("a")
                g = normalized.get("g")
                if a is not None and g is not None:
                    ea = _mean_eig(a, info.spec.a_kind, info.d_in)
                    eg = _mean_eig(g, info.spec.g_kind, info.d_out)
                    pi = jnp.sqrt(jnp.maximum(ea, 1e-12) / jnp.maximum(eg, 1e-12))
                else:
                    pi = jnp.ones(a.shape[:len(info.lead)] if a is not None
                                  else g.shape[:len(info.lead)])
                sl = jnp.sqrt(jnp.asarray(lam, jnp.float32))
                with jax.named_scope(STAGE_INVERSE):
                    for key, stat, d in (("a", a, pi * sl), ("g", g, sl / pi)):
                        if stat is None:
                            continue
                        kind = (info.spec.a_kind if key == "a"
                                else info.spec.g_kind)
                        if key in info_keys:
                            pc[key], inv_info[key] = self._stat_inverse(
                                fam, key, stat, kind, d, want_info=True)
                        else:
                            pc[key] = self._stat_inverse(fam, key, stat,
                                                         kind, d)
            for key in ("d", "uw"):
                if key in normalized:
                    pc[key] = normalized[key]
            if "uwf" in normalized:
                # full BN Fisher (2C x 2C): invert directly with lam damping
                pc["uwf"] = kfac.damped_inverse(
                    normalized["uwf"], jnp.asarray(lam, jnp.float32))
            return pc, inv_info

        def keep(_):
            # not-refreshed sentinels: ns_res=-1 (no inversion ran this
            # step), converged=True — shape-matched to recompute's info so
            # the cond branches return identical pytrees
            inv_info = {k: {"ns_res": jnp.full(normalized[k].shape[:-2],
                                               -1.0, jnp.float32),
                            "ns_converged": jnp.full(
                                normalized[k].shape[:-2], True)}
                        for k in info_keys}
            return curv["precond_next" if cfg.double_buffer else "precond"], \
                inv_info

        precond, inv_info = jax.lax.cond(any_flag, recompute, keep, None)
        if cfg.double_buffer:
            # pipeline: the fresh inverses are STAGED (precond_next) and the
            # buffer staged by the latest earlier refresh activates for this
            # step — refresh at t produces inverses consumed from t+1 on
            out = {"prev": new_prev, "precond": curv["precond_next"],
                   "precond_next": precond}
        else:
            out = {"prev": new_prev, "precond": precond}
        out["prev2"] = new_prev2
        return out, sims, inv_info

    def _stat_inverse(self, fam: str, key: str, stat: jax.Array, kind: str,
                      damp: jax.Array, want_info: bool = False):
        """One factor's Stage-4 inverse: shard-local + all-gather when a
        :class:`~repro.comm.Stage4Inverter` is attached (full-kind factors
        only — diagonal kinds are elementwise and not worth a collective),
        the replicated path otherwise.

        ``want_info=True`` returns ``(inv, info)`` where info is the
        per-block ``{"ns_res", "ns_converged"}`` dict (None for non-full
        kinds). The sharded path's extra ``owner`` vector is dropped so the
        info pytree is identical across both Stage-4 call sites — the
        refresh ``lax.cond`` requires matched branch trees."""
        cfg = self.cfg
        if kind == "full" and self.stage4 is not None:
            out = self.stage4.invert(stat, damp, fam=fam, key=key,
                                     return_info=want_info)
            if not want_info:
                return out
            inv, info = out
            return inv, {"ns_res": info["ns_res"],
                         "ns_converged": info["ns_converged"]}
        out = _damped_inv(stat, kind, damp, cfg.inverse_method, cfg.backend,
                          cfg.ns_iters, cfg.ns_tol, return_info=want_info)
        return out

    # ---- preconditioned update for one family ----

    def _apply_precond(self, fam: str, grads, curv: dict, lam):
        info = self.infos[fam]
        pc = curv["precond"]
        if info.kind in ("dense", "grouped", "embed"):
            dw = get_path(grads, info.param)
            u = kfac.precondition(dw, pc.get("a"), pc.get("g"),
                                  backend=self.cfg.backend)
            return {info.param: u}
        if info.kind == "conv":
            dw = get_path(grads, info.param)       # (kh, kw, cin, cout)
            kh, kw, cin, cout = dw.shape[-4:]
            lead = dw.shape[:-4]
            d2 = jnp.transpose(dw, tuple(range(len(lead))) +
                               tuple(len(lead) + i for i in (2, 0, 1, 3)))
            d2 = d2.reshape(lead + (cin * kh * kw, cout))
            u = kfac.precondition(d2, pc.get("a"), pc.get("g"),
                                  backend=self.cfg.backend)
            u = u.reshape(lead + (cin, kh, kw, cout))
            u = jnp.transpose(u, tuple(range(len(lead))) +
                              tuple(len(lead) + i for i in (1, 2, 0, 3)))
            return {info.param: u}
        if info.kind == "bias":
            g = get_path(grads, info.param)
            return {info.param: kfac.diag_solve(pc["d"], g, lam)}
        if info.kind == "scale_bias":
            gg = get_path(grads, info.param)
            if "uwf" in pc:                    # full BN Fisher baseline
                gb = get_path(grads, info.beta_param)
                gcat = jnp.concatenate([gg, gb], axis=-1)
                u = jnp.einsum("...ab,...b->...a", pc["uwf"],
                               gcat.astype(jnp.float32))
                c = gg.shape[-1]
                return {info.param: u[..., :c], info.beta_param: u[..., c:]}
            if info.beta_param is not None:
                gb = get_path(grads, info.beta_param)
                ug, ub = kfac.unitwise_solve(pc["uw"], gg, gb, lam)
                return {info.param: ug, info.beta_param: ub}
            ug = kfac.diag_solve(pc["uw"][..., 0], gg, lam)
            return {info.param: ug}
        raise ValueError(info.kind)

    # ---- full update assembly ----

    def _finish(self, params, state, grads, curv, lam, lr, mom, loss, aux,
                sims, inverse_info: Optional[dict] = None,
                extra_metrics: Optional[dict] = None):
        from repro.obs.tracing import STAGE_PRECOND
        cfg = self.cfg
        # preconditioned updates for sited params
        updates = {}
        with jax.named_scope(STAGE_PRECOND):
            for fam, c in curv.items():
                updates.update(self._apply_precond(fam, grads, c, lam))

        sited = set(updates)

        def leaf_update(path_str, g):
            if path_str in updates:
                return updates[path_str]
            return g * cfg.sgd_fallback_scale     # non-sited: first-order

        flat_g = _flatten_paths(grads)
        flat_p = _flatten_paths(params)
        flat_v = _flatten_paths(state["velocity"])
        new_p, new_v = {}, {}
        gsq = usq = jnp.zeros((), jnp.float32)
        for path_str, g in flat_g.items():
            u = leaf_update(path_str, g)
            gsq += jnp.sum(jnp.square(g.astype(jnp.float32)))
            usq += jnp.sum(jnp.square(u.astype(jnp.float32)))
            v = mom * flat_v[path_str] - lr * u.astype(flat_v[path_str].dtype)
            w = flat_p[path_str] + v.astype(flat_p[path_str].dtype)
            new_v[path_str] = v
            new_p[path_str] = w

        # Eq. 24 weight rescaling on dense/conv/grouped weights
        if cfg.weight_rescale:
            for fam, info in self.infos.items():
                if info.kind in ("dense", "conv", "grouped"):
                    w = new_p[info.param]
                    naxes = 2 if info.kind in ("dense", "grouped") else 4
                    axes = tuple(range(w.ndim - naxes, w.ndim))
                    norm = jnp.sqrt(jnp.sum(w.astype(jnp.float32) ** 2, axis=axes,
                                            keepdims=True))
                    target = jnp.sqrt(2.0 * info.d_out)
                    new_p[info.param] = (w * (target / (norm + cfg.rescale_eps))
                                         ).astype(w.dtype)

        params_out = _unflatten_paths(new_p, like=params)
        vel_out = _unflatten_paths(new_v, like=params)
        # spread: auxiliary state (e.g. the refresh pipeline's cursor/raw
        # store, already advanced by the caller) rides through unchanged
        state_out = {**state, "step": state["step"] + 1, "velocity": vel_out,
                     "curv": curv}
        metrics = {"loss": loss, "sims": sims,
                   "grad_norm": jnp.sqrt(gsq), "update_norm": jnp.sqrt(usq)}
        if inverse_info:
            metrics["inverse_info"] = inverse_info
        if extra_metrics:
            metrics.update(extra_metrics)
        if isinstance(aux, dict):
            metrics.update({k: v for k, v in aux.items()
                            if isinstance(v, jax.Array) and v.ndim == 0})
        return params_out, state_out, metrics

    def grads_and_raw(self, params, batch,
                      rng: Optional[jax.Array] = None):
        """One backward pass: (loss, aux, grads, raw factor sums). Exposed
        separately so the launch layer can accumulate over microbatches —
        the paper's own method for mimicking BS=65K/131K (§7.1)."""
        from repro.obs.tracing import STAGE_CAPTURE
        fstats = self.fstats_fn()
        with jax.named_scope(STAGE_CAPTURE):
            if self.cfg.estimator == "1mc":
                return mc_fisher_grads(self.loss_fn, params, fstats, batch,
                                       rng)
            return emp_fisher_grads(self.loss_fn, params, fstats, batch)

    def apply_update(self, params, state, grads, raw, counts, flags,
                     lam, lr, mom, loss, aux):
        """Refresh curvature from raw sums (per ``flags``) + apply Eq. 23.

        With the chunked pipeline on (``refresh_chunks > 1``) this is the
        CAPTURE step: history/similarities update as usual but the
        inversions are deferred to the next K fast steps' drains."""
        if self.pipeline is not None:
            return self._apply_capture(params, state, grads, raw, counts,
                                       flags, lam, lr, mom, loss, aux)
        curv, sims, inv_info = {}, {}, {}
        for fam in raw:
            n_a, n_g = counts[fam]
            curv[fam], s, fi = self._refresh_family(
                fam, raw[fam], state["curv"][fam], flags, lam, n_a, n_g)
            sims.update(s)
            for key, v in fi.items():
                inv_info[f"{fam}.{key}"] = v
        return self._finish(params, state, grads, curv, lam, lr, mom,
                            loss, aux, sims, inverse_info=inv_info)

    def _apply_capture(self, params, state, grads, raw, counts, flags,
                       lam, lr, mom, loss, aux):
        """Pipeline-mode refresh trigger: normalize + measure sims + shift
        history (so Algorithm 2 sees this step's similarities), park the
        normalized statistics in the raw store, and restart the drain
        cursor. No inversion runs here — this step's cost over a fast step
        is capture + Stage-3 reduce only. A pending (fully drained, not yet
        activated) refresh flips first so it is consumed, not lost."""
        pipe = state["pipeline"]
        curv_in = self.pipeline.flip(state["curv"], pipe)
        curv, sims = {}, {}
        new_raw, new_valid = {}, {}
        for fam in raw:
            n_a, n_g = counts[fam]
            normalized, new_prev, new_prev2, s = self._shift_history(
                fam, raw[fam], curv_in[fam], flags, n_a, n_g)
            sims.update(s)
            curv[fam] = {**curv_in[fam], "prev": new_prev,
                         "prev2": new_prev2}
            new_raw[fam] = normalized
            new_valid[fam] = {
                k: jnp.logical_or(pipe["valid"][fam][k],
                                  flags[f"{fam}.{k}"])
                for k in raw[fam]}
        pipe = {"cursor": jnp.zeros((), jnp.int32), "raw": new_raw,
                "valid": new_valid}
        state = {**state, "pipeline": pipe}
        extra = {"refresh_inflight": jnp.asarray(
            self.pipeline.chunks + 1, jnp.int32)}
        return self._finish(params, state, grads, curv, lam, lr, mom,
                            loss, aux, sims, extra_metrics=extra)

    def fast_curv(self, state, lam):
        """The fast path's curvature view + any pipeline progress: drains
        one chunk (and/or flips) when the pipeline is on, otherwise the
        plain double-buffer activation. Returns ``(state, curv, extra)``
        where ``extra`` feeds ``_finish``'s metrics (``refresh_inflight``
        in pipeline mode, empty otherwise). Every fast-step builder goes
        through here so the drain cannot be skipped by a schedule."""
        if self.pipeline is None:
            return state, self._activate(state["curv"]), {}
        curv, pipe, inflight = self.pipeline.drain(
            state["curv"], state["pipeline"], lam)
        return ({**state, "pipeline": pipe}, curv,
                {"refresh_inflight": inflight})

    def step(self, params, state, batch, flags: dict, lam, lr, mom,
             rng: Optional[jax.Array] = None):
        """Full step with curvature capture. ``flags`` maps stat_name ->
        bool (traced ok)."""
        loss, aux, grads, raw = self.grads_and_raw(params, batch, rng)
        counts = self.counts_fn(batch)
        return self.apply_update(params, state, grads, raw, counts, flags,
                                 lam, lr, mom, loss, aux)

    def step_fast(self, params, state, batch, lam, lr, mom):
        """No capture, no refresh: backward + stale-preconditioned update
        (plus one pipeline drain chunk when ``refresh_chunks > 1``)."""
        (loss, aux), grads = jax.value_and_grad(
            self.loss_fn, has_aux=True)(params, None, batch)
        state, curv, extra = self.fast_curv(state, lam)
        return self._finish(params, state, grads, curv, lam, lr, mom,
                            loss, aux, {}, extra_metrics=extra)

    # ---- double-buffer plumbing ----

    def _activate(self, curv: dict) -> dict:
        """Double-buffer activation: the buffer staged by the latest refresh
        becomes the active preconditioner for THIS step (``_finish`` then
        persists the swap into the state). Identity when the pipeline is
        off. The refresh path performs its own activation inside
        ``_refresh_family``; this one covers the fast (no-capture) steps.
        With the chunked pipeline on this is also identity — activation is
        then the drain's gated flip (``RefreshPipeline.flip``), never an
        unconditional swap of a half-written ``precond_next``."""
        if not self.cfg.double_buffer or self.pipeline is not None:
            return curv
        return {fam: {**entry, "precond": entry["precond_next"]}
                for fam, entry in curv.items()}

    def upgrade_state(self, state: dict) -> dict:
        """Adapt a loaded optimizer state to this config's buffer layout
        (checkpoint compat across the double-buffer introduction): a
        single-buffer checkpoint entering a ``double_buffer`` run seeds the
        staged buffer from the active one (the first activation is then a
        no-op — the run continues exactly where the old semantics left it);
        a double-buffered checkpoint entering a single-buffer run drops the
        staged copy. Same-layout states pass through unchanged.

        The chunked-pipeline state follows the same rules: a checkpoint
        without it entering a ``refresh_chunks > 1`` run seeds an idle
        pipeline (cursor parked, nothing valid — the next capture starts
        it); a mid-drain checkpoint entering an inline run drops the
        pipeline state, losing only the not-yet-activated refresh (the
        next inline refresh recomputes it). A mid-drain state resuming
        under the SAME chunk count continues bit-identically — the cursor,
        raw store and valid latches are ordinary jnp leaves."""
        state = dict(state)
        curv = {}
        for fam, entry in state["curv"].items():
            entry = dict(entry)
            if self.cfg.double_buffer and "precond_next" not in entry:
                entry["precond_next"] = dict(entry["precond"])
            if not self.cfg.double_buffer:
                entry.pop("precond_next", None)
            curv[fam] = entry
        if self.pipeline is not None and "pipeline" not in state:
            state["pipeline"] = self.pipeline.init_state()
        if self.pipeline is None:
            state.pop("pipeline", None)
        return {**state, "curv": curv}


# ---------------------------------------------------------------------------
# path-keyed flatten helpers (params are nested dicts)
# ---------------------------------------------------------------------------

def _flatten_paths(tree, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_paths(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_paths(flat: dict, like) -> dict:
    def rec(node, prefix):
        if isinstance(node, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in node.items()}
        return flat[prefix[:-1]]
    return rec(like, "")
