"""Fisher information estimation (paper §3.2, §4.1).

Two estimators, matching the paper's `emp` vs `1mc` ablation:

* ``emp`` — empirical Fisher (Eq. 13): factor statistics are captured during
  the *single* ordinary backward pass via the tagged sites (zero extra
  passes; the paper's headline "practical" technique).
* ``1mc`` — one-sample Monte-Carlo Fisher (Eq. 5): labels are *sampled* from
  the model's predictive distribution and an extra backward pass computes the
  statistics. Implemented for the ablation benchmark; it is strictly slower,
  which is the paper's point.

Normalization: tagged sites return RAW sums over local tokens. With the
mean-over-samples loss, the properly scaled factors are

    A  = raw_a / n_a                (n_a = #tokens that hit the site)
    G  = raw_g * n_g                (n_g = #samples the loss averages over)
    d  = raw_d * n_g                (diagonal Fisher, biases)
    uw = raw_uw * n_g               (unit-wise 2x2 stats)
    A_embed = raw_counts / n_a      (token frequency diagonal)

because the per-sample log-likelihood gradient is ``n_loss * dL/ds`` and
``n_loss == n_g``. For LM sites n_a == n_g == B*S; for conv sites n_a ==
B*Ho*Wo while n_g == B (paper Eq. 11's 1/hw spatial normalization on A).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.tagging import FactorSpec


# ---------------------------------------------------------------------------
# Site registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SiteInfo:
    """Static metadata tying one tagged site to its parameter leaf.

    ``param`` is a '/'-joined path into the params pytree. ``lead`` is the
    leading axes shared by the factor arrays and the parameter (e.g. ``(L,)``
    for scan-stacked layers, ``(L, E)`` for stacked MoE experts).
    """
    kind: str                      # dense | grouped | conv | embed | bias | scale_bias
    param: str
    d_in: int = 0
    d_out: int = 0
    spec: FactorSpec = FactorSpec()
    lead: tuple = ()
    ksize: int = 1                 # conv: spatial kernel (d_in = cin*k*k)
    beta_param: Optional[str] = None   # scale_bias: path of the bias leaf


def get_path(tree: Any, path: str) -> Any:
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def set_path(tree: dict, path: str, value: Any) -> dict:
    """Functionally set ``path`` in a nested-dict pytree."""
    parts = path.split("/")
    def rec(node, i):
        out = dict(node)
        if i == len(parts) - 1:
            out[parts[i]] = value
        else:
            out[parts[i]] = rec(node[parts[i]], i + 1)
        return out
    return rec(tree, 0)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def normalize_stats(raw: dict, infos: dict[str, SiteInfo],
                    counts: dict[str, tuple]) -> dict:
    """raw: {family: {"a"|"g"|"d"|"uw": raw sums}} -> scaled factors."""
    out = {}
    for fam, stats in raw.items():
        n_a, n_g = counts[fam]
        o = {}
        for key, v in stats.items():
            if key == "a":
                o[key] = v / n_a
            else:            # g, d, uw all scale by n_g
                o[key] = v * n_g
        out[fam] = o
    return out


# ---------------------------------------------------------------------------
# Gradient + statistics in one (emp) or two (1mc) backward passes
# ---------------------------------------------------------------------------

def emp_fisher_grads(loss_fn: Callable, params, fstats, batch):
    """loss_fn(params, fstats, batch) -> (loss, aux). Single backward pass
    computes grads AND raw factor sums (the paper's `emp` path)."""
    (loss, aux), (g_params, g_stats) = jax.value_and_grad(
        loss_fn, argnums=(0, 1), has_aux=True)(params, fstats, batch)
    return loss, aux, g_params, g_stats


def mc_fisher_grads(loss_fn: Callable, params, fstats, batch, rng,
                    label_key: str = "labels"):
    """`1mc` estimator (Eq. 5): grads from the true labels, factor statistics
    from one extra backward pass against labels sampled from p_theta.

    ``aux`` must contain "logits" (pre-softmax, (..., V))."""
    (loss, aux), g_params = jax.value_and_grad(
        loss_fn, has_aux=True)(params, None, batch)
    logits = aux["logits"]
    sampled = jax.random.categorical(rng, logits.astype(jnp.float32), axis=-1)
    batch_mc = dict(batch)
    batch_mc[label_key] = sampled.reshape(batch[label_key].shape)
    # extra backward pass, statistics only
    g_stats = jax.grad(lambda fs: loss_fn(params, fs, batch_mc)[0])(fstats)
    return loss, aux, g_params, g_stats
