"""Chunked refresh pipeline: hide Stage-4 behind training compute.

The paper's headline overhead claim (§5.2, Fig. 10) is that SP-NGD fast
steps cost what SGD costs because the curvature refresh is *hidden behind
training compute*: stale statistics (Alg. 2) make it legitimate to spread
one refresh over the whole staleness interval instead of paying it inline
on the refresh step. PR 7 shipped the staging seam (``precond_next`` +
activation at t+1); this module spreads the work.

Decomposition
-------------
A refresh splits into a **capture** step and ``K = NGDConfig.refresh_chunks``
**drain** chunks:

* The capture step (the step where Algorithm 1 raises refresh flags) runs
  fwd/bwd + Stage-2 capture + the Stage-3 reduce + normalization, measures
  the Frobenius similarities the IntervalController needs *that step*, and
  shifts the X₋₁/X₋₂ history — but performs NO inversions. The normalized
  f32 statistics are parked in the optimizer state
  (``opt_state["pipeline"]["raw"]``).
* Each of the next K fast steps executes one **chunk** — a set of whole
  (family, stat) inversion + gather units, LPT-balanced by a flop model —
  inside the same jitted program as that step's fwd/bwd, so XLA overlaps
  the chunk's eigh/NS compute and its gather collective with training
  compute. Full-kind factors route through the attached
  :class:`repro.comm.Stage4Inverter` exactly as the inline refresh does.
* The step after the last chunk **flips** ``precond_next -> precond``
  atomically per statistic — the same activation contract as
  ``SPNGD._activate``, just ``K+1`` steps after the capture instead of 1.

Chunks recompute from the parked raw statistics with the same ops as
``SPNGD._refresh_family``'s inline recompute (same pi split, same damping,
same inverse dispatch), so a drained refresh is bit-identical to an inline
double-buffered refresh of the same statistics — only the activation step
moves. The interval controller's ``min_interval = K + 1`` floor guarantees
a drain finishes before the next capture can start; a capture arriving
mid-drain (possible when per-stat schedules are offset) simply restarts the
cursor, re-deriving the in-flight chunks from the refreshed raw store —
idempotent, never wrong, at worst ``K`` duplicate chunk executions.

State machine
-------------
``opt_state["pipeline"] = {"cursor", "raw", "valid"}`` — all jnp leaves, so
the whole machine checkpoints/donates/shards like any other optimizer
state. ``cursor`` semantics (K = refresh_chunks):

    0..K-1   next drain step executes chunk ``cursor``
    K        all chunks written; next step flips precond_next -> precond
    K+1      idle (init / after the flip)

``valid[fam][key]`` latches once a statistic has been captured at least
once; the flip is gated on it so a never-captured statistic's identity
preconditioner is never replaced by an inverse of zeros.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kfac


def _unit_cost(shape: tuple, kind: str) -> int:
    """Relative flop cost of one inversion unit (the LPT balance weight):
    blocked eigh/NS ~ lead x b^3 for full kinds, elementwise ~ n for the
    diagonal/unit-wise kinds (copies, effectively free)."""
    if kind == "full" and len(shape) >= 2:
        lead = int(np.prod(shape[:-2], dtype=np.int64))
        return max(1, lead * int(shape[-1]) ** 3)
    return max(1, int(np.prod(shape, dtype=np.int64)))


class RefreshPipeline:
    """Owns chunk scheduling for one :class:`repro.core.ngd.SPNGD`.

    Construction is host-side and static: the (family, stat) -> chunk
    assignment is pure shape arithmetic over the ``fstats`` template, so
    the drain's ``lax.switch`` branches are fixed at trace time. The traced
    entry points are :meth:`flip` (activation) and :meth:`drain` (one chunk
    + cursor advance), both called from the optimizer's fast path.
    """

    def __init__(self, opt, chunks: int):
        if chunks < 1:
            raise ValueError("refresh_chunks must be >= 1")
        self.opt = opt
        self.chunks = int(chunks)
        template = jax.eval_shape(opt.fstats_fn)
        from repro.core.ngd import _dense_leaf_shape
        units = []                      # (fam, key, cost)
        self._shapes: dict[str, tuple] = {}
        for fam, stats in sorted(template.items()):
            info = opt.infos[fam]
            for key, leaf in sorted(stats.items()):
                shape = _dense_leaf_shape(leaf)
                self._shapes[f"{fam}.{key}"] = shape
                if key in ("a", "g"):
                    kind = (info.spec.a_kind if key == "a"
                            else info.spec.g_kind)
                elif key == "uwf":
                    kind = "full"
                else:                   # "d" / "uw": stats pass through
                    kind = "elem"
                units.append((fam, key, _unit_cost(shape, kind)))
        # LPT (longest processing time first): heaviest unit to the
        # lightest chunk — near-optimal makespan, deterministic tiebreaks
        units.sort(key=lambda u: (-u[2], u[0], u[1]))
        loads = [0] * self.chunks
        self.schedule: list[list[tuple[str, str]]] = [
            [] for _ in range(self.chunks)]
        for fam, key, cost in units:
            i = loads.index(min(loads))
            self.schedule[i].append((fam, key))
            loads[i] += cost
        self.loads = loads

    # ---- host-side views ----

    def chunk_names(self, i: int) -> list[str]:
        """The statistics chunk ``i`` inverts (metrics span labels)."""
        return [f"{fam}.{key}" for fam, key in self.schedule[i]]

    # ---- state ----

    def init_state(self) -> dict:
        """Fresh (idle) pipeline state: cursor parked at K+1, raw store
        zeroed, nothing valid."""
        raw, valid = {}, {}
        for name, shape in self._shapes.items():
            fam, key = name.split(".", 1)
            raw.setdefault(fam, {})[key] = jnp.zeros(shape, jnp.float32)
            valid.setdefault(fam, {})[key] = jnp.zeros((), bool)
        return {"cursor": jnp.full((), self.chunks + 1, jnp.int32),
                "raw": raw, "valid": valid}

    # ---- traced entry points ----

    def flip(self, curv: dict, pipe: dict) -> dict:
        """Activate a completed drain: when ``cursor == K`` every valid
        statistic's ``precond_next`` becomes ``precond`` (atomic per stat —
        a chunk never half-activates). No-op at any other cursor."""
        do = pipe["cursor"] == self.chunks
        out = {}
        for fam, entry in curv.items():
            pc = {}
            for key, cur in entry["precond"].items():
                on = jnp.logical_and(do, pipe["valid"][fam][key])
                pc[key] = jnp.where(on, entry["precond_next"][key], cur)
            out[fam] = {**entry, "precond": pc}
        return out

    def drain(self, curv: dict, pipe: dict, lam):
        """One fast step's pipeline work: flip if the drain just completed,
        execute chunk ``cursor`` (no-op when idle), advance the cursor.

        Returns ``(curv', pipe', inflight)`` where ``inflight`` is the
        int32 number of steps until the in-flight refresh is live (K+1
        right after a capture, 1 on the flip step, 0 when idle) — the
        metrics stream's ``refresh_inflight`` field. ``lam`` is the
        drain-time damping; under the stock schedules lambda is constant
        over a run, so it equals the capture-time value.
        """
        from repro.obs.tracing import STAGE_CHUNK
        k = self.chunks
        cursor = pipe["cursor"]
        curv = self.flip(curv, pipe)
        pnext = {fam: entry["precond_next"] for fam, entry in curv.items()}

        def wrap(i, fn):
            def branch(op):
                with jax.named_scope(f"{STAGE_CHUNK}[{i}/{k}]"):
                    return fn(*op)
            return branch

        branches = [wrap(i, self._chunk_fn(i)) for i in range(k)]
        branches.append(lambda op: op[0])          # idle / flip-step no-op
        pnext = jax.lax.switch(jnp.minimum(cursor, k), branches,
                               (pnext, pipe["raw"], lam))
        curv = {fam: {**entry, "precond_next": pnext[fam]}
                for fam, entry in curv.items()}
        inflight = jnp.clip(k + 1 - cursor, 0, k + 1).astype(jnp.int32)
        pipe = {**pipe, "cursor": jnp.minimum(cursor + 1, k + 1)}
        return curv, pipe, inflight

    # ---- chunk bodies ----

    def _pi(self, fam: str, raw: dict) -> jax.Array:
        """The family's pi = sqrt(mean_eig(A)/mean_eig(G)) damping split —
        same formula as the inline recompute; both factors read from the
        raw store, so pi is chunk-assignment invariant."""
        from repro.core.ngd import _mean_eig
        info = self.opt.infos[fam]
        a = raw[fam].get("a")
        g = raw[fam].get("g")
        if a is not None and g is not None:
            ea = _mean_eig(a, info.spec.a_kind, info.d_in)
            eg = _mean_eig(g, info.spec.g_kind, info.d_out)
            return jnp.sqrt(jnp.maximum(ea, 1e-12) / jnp.maximum(eg, 1e-12))
        ref = a if a is not None else g
        return jnp.ones(ref.shape[:len(info.lead)])

    def _chunk_fn(self, i: int):
        """Branch body for chunk ``i``: invert this chunk's units from the
        raw store and write them (whole stats) into ``precond_next``."""
        units = self.schedule[i]

        def run(pnext, raw, lam):
            sl = jnp.sqrt(jnp.asarray(lam, jnp.float32))
            out = {fam: dict(stats) for fam, stats in pnext.items()}
            for fam, key in units:
                info = self.opt.infos[fam]
                v = raw[fam][key]
                if key in ("a", "g"):
                    kind = (info.spec.a_kind if key == "a"
                            else info.spec.g_kind)
                    pi = self._pi(fam, raw)
                    damp = pi * sl if key == "a" else sl / pi
                    # routes through the attached Stage4Inverter when
                    # inverse_sharding is on — shard-local + gather, one
                    # collective per chunk unit
                    out[fam][key] = self.opt._stat_inverse(fam, key, v,
                                                           kind, damp)
                elif key == "uwf":
                    out[fam][key] = kfac.damped_inverse(
                        v, jnp.asarray(lam, jnp.float32))
                else:                   # "d" / "uw": stats pass through
                    out[fam][key] = v
            return out

        return run
