"""Kronecker-factored curvature math (paper §3.3, §4).

Conventions
-----------
* Weights are stored ``(d_in, d_out)``; a dense site computes ``y = x @ w``.
* Tokens-as-samples empirical Fisher: with ``n`` the number of tokens that
  flowed through a site, the Kronecker factors are

      A = (1/n) sum_t a_t a_t^T          (input second moment)
      G = (1/n) sum_t ghat_t ghat_t^T    (output log-likelihood grad 2nd moment)

  where ``ghat = n * dL/ds`` undoes the mean-loss scaling, so
  ``G_raw = sum_t (dL/ds)(dL/ds)^T`` relates as ``G = n * G_raw``.
* The natural-gradient update for the site is ``U = A^-1 @ dW @ G^-1``
  (``F = G (x) A`` for vec in our layout; Eq. 6/12 of the paper).
* Large dimensions are split into diagonal blocks of at most ``max_dim``
  ("block-diagonal factor capping", DESIGN.md §4) and every factor array
  carries a leading block axis ``(nb, b, b)`` — possibly with further leading
  layer / expert axes. All ops here broadcast over leading axes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Block partitioning
# ---------------------------------------------------------------------------

def num_blocks(d: int, max_dim: int) -> int:
    """Number of diagonal blocks a dimension of size ``d`` is split into."""
    return max(1, -(-d // max_dim))


def block_size(d: int, max_dim: int) -> int:
    """Uniform (padded) block size used for a dimension of size ``d``."""
    nb = num_blocks(d, max_dim)
    return -(-d // nb)


def padded_dim(d: int, max_dim: int) -> int:
    return num_blocks(d, max_dim) * block_size(d, max_dim)


def block_reshape(x: jax.Array, d: int, max_dim: int, axis: int = -1) -> jax.Array:
    """Reshape ``axis`` (size d) into (nb, b), zero-padding to nb*b."""
    nb = num_blocks(d, max_dim)
    b = block_size(d, max_dim)
    axis = axis % x.ndim
    pad = nb * b - d
    if pad:
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, pad)
        x = jnp.pad(x, cfg)
    new_shape = x.shape[:axis] + (nb, b) + x.shape[axis + 1:]
    return x.reshape(new_shape)


def block_unreshape(x: jax.Array, d: int, axis: int = -2) -> jax.Array:
    """Inverse of :func:`block_reshape`: merge (nb, b) at ``axis`` back to d."""
    axis = axis % x.ndim
    nb, b = x.shape[axis], x.shape[axis + 1]
    merged = x.reshape(x.shape[:axis] + (nb * b,) + x.shape[axis + 2:])
    if nb * b != d:
        merged = jax.lax.slice_in_dim(merged, 0, d, axis=axis)
    return merged


# ---------------------------------------------------------------------------
# Factor statistics from token matrices
# ---------------------------------------------------------------------------

def factor_sum(x: jax.Array, max_dim: int, *,
               backend: Optional[str] = None) -> jax.Array:
    """Blocked ``sum_t x_t x_t^T`` for a token matrix ``x`` of shape
    (..., n, d). Returns (..., nb, b, b) in f32.

    Inputs stay in their storage dtype (bf16 on TPU) with f32 accumulation —
    the paper's mixed-precision Tensor-Core statistics construction (§5.2)
    mapped to the MXU; it also halves any sharding-induced traffic on x.

    ``backend`` selects the implementation ("ref" | "pallas" | "auto", see
    :mod:`repro.kernels.dispatch`)."""
    from repro.kernels import dispatch
    return dispatch.factor_sum(x, max_dim, backend=backend)


def factor_sum_wire(x: jax.Array, max_dim: int, *, fmt: str = "e4m3",
                    scale_mode: str = "fp32",
                    backend: Optional[str] = None):
    """Fused :func:`factor_sum` + wire-format epilogue: returns
    ``(payload fp8 (..., nb, t), scale f32 (..., nb))`` — the sym-packed
    per-block-quantized tile the Stage-3 "fused" strategy puts on the wire
    (see :mod:`repro.kernels.dispatch` ``factor_sum_wire``)."""
    from repro.kernels import dispatch
    return dispatch.factor_sum_wire(x, max_dim, fmt=fmt,
                                    scale_mode=scale_mode, backend=backend)


def diag_factor_sum(x: jax.Array) -> jax.Array:
    """``sum_t x_t^2`` per output coordinate. (..., n, d) -> (..., d)."""
    x = x.astype(jnp.float32)
    return jnp.sum(x * x, axis=-2)


# ---------------------------------------------------------------------------
# Damping + inversion (Eq. 12)
# ---------------------------------------------------------------------------

def _block_trace(f: jax.Array) -> jax.Array:
    """Trace summed over the block axis. f: (..., nb, b, b) -> (...,)."""
    return jnp.trace(f, axis1=-2, axis2=-1).sum(-1)


def pi_correction(a: jax.Array, g: jax.Array, d_a: int, d_g: int,
                  eps: float = 1e-12) -> jax.Array:
    """Martens-Grosse pi: sqrt(mean_eig(A) / mean_eig(G)) via traces.

    ``a``: (..., nbA, bA, bA), ``g``: (..., nbG, bG, bG); returns (...,).
    ``d_a``/``d_g`` are the true (unpadded) dimensions.
    """
    tr_a = _block_trace(a) / d_a
    tr_g = _block_trace(g) / d_g
    return jnp.sqrt(jnp.maximum(tr_a, eps) / jnp.maximum(tr_g, eps))


def damped_inverse(f: jax.Array, damping: jax.Array) -> jax.Array:
    """Inverse of SPD blocked factor ``f + damping*I``.

    f: (..., nb, b, b); damping broadcastable to (...,). Uses eigh for
    robustness (clamps negative eigenvalues that appear from bf16
    accumulation). bf16 inputs solve in f32 (LAPACK has no bf16 eigh);
    outputs are f32 either way."""
    f = f.astype(jnp.float32)
    f = 0.5 * (f + jnp.swapaxes(f, -1, -2))  # re-symmetrize
    vals, vecs = jnp.linalg.eigh(f)
    d = jnp.asarray(damping)[..., None]  # broadcast over the eigenvalue axis
    inv_vals = 1.0 / (jnp.maximum(vals, 0.0) + d)
    return jnp.einsum("...ab,...b,...cb->...ac", vecs, inv_vals, vecs)


def cholesky_inverse(f: jax.Array, damping: jax.Array) -> jax.Array:
    """Cheaper inverse via Cholesky; requires f SPD after damping.
    Solves in f32 like :func:`damped_inverse` (no bf16 LAPACK)."""
    b = f.shape[-1]
    f = f.astype(jnp.float32)
    f = 0.5 * (f + jnp.swapaxes(f, -1, -2))
    d = jnp.asarray(damping)[..., None, None]
    eye = jnp.eye(b, dtype=f.dtype)
    fd = f + d * eye
    chol = jnp.linalg.cholesky(fd)
    return jax.scipy.linalg.cho_solve((chol, True), jnp.broadcast_to(eye, fd.shape))


# Newton-Schulz knobs, defined ONCE here (the algorithm's home): everything
# downstream — dispatch.damped_inverse, NGDConfig.ns_iters/ns_tol — defaults
# to these, so tuning the cap or tolerance is a one-line change.
NS_ITERS = 40   # iteration cap: covers damped condition numbers ~1e4 in f32
NS_TOL = 1e-4   # relative fixed-point residual for early exit / fallback


def newton_schulz_inverse(f: jax.Array, damping: jax.Array, *,
                          iters: int = NS_ITERS,
                          tol: float = NS_TOL) -> tuple[jax.Array, jax.Array]:
    """Matmul-only blocked inverse of ``f + damping*I`` (Newton-Schulz).

    The iteration ``X_{k+1} = X_k (2I - M X_k)`` with the spectral-norm
    upper-bound init ``X_0 = M^T / (||M||_1 ||M||_inf)`` converges
    quadratically for SPD ``M = f + damping*I`` (every eigenvalue of
    ``M X_0`` lies in (0, 1]); this is the pure-jnp reference for the
    Stage-4 Pallas kernel — the inverse built from nothing but GEMMs.

    Per block, iterates freeze once the relative fixed-point residual
    ``||I - M X_k||_F / ||I||_F`` drops to ``tol`` (the early exit); the
    cap ``iters`` bounds the work for blocks that never contract that far.

    f: (..., nb, b, b); damping broadcastable like :func:`damped_inverse`.
    Returns ``(x, res)`` with ``res`` (..., nb) the relative residual of
    the RETURNED iterate — callers use ``res > tol`` as the
    failed-to-contract predicate (ill-conditioned block -> eigh fallback
    in :mod:`repro.kernels.dispatch`).
    """
    b = f.shape[-1]
    f = f.astype(jnp.float32)
    f = 0.5 * (f + jnp.swapaxes(f, -1, -2))
    # damping follows the damped_inverse broadcast convention: (...,) or
    # (..., 1) against the block axis -> expand over (nb,) then the matrix
    d = jnp.broadcast_to(jnp.asarray(damping, jnp.float32), f.shape[:-2])
    eye = jnp.eye(b, dtype=jnp.float32)
    m = f + d[..., None, None] * eye
    # ||M||_1 * ||M||_inf >= ||M||_2^2, so every eigenvalue of M X_0 is in
    # (0, 1] and I - M X_0 is a contraction
    n1 = jnp.max(jnp.sum(jnp.abs(m), axis=-2), axis=-1)
    ninf = jnp.max(jnp.sum(jnp.abs(m), axis=-1), axis=-1)
    x0 = jnp.swapaxes(m, -1, -2) / (n1 * ninf)[..., None, None]
    rnorm = 1.0 / np.sqrt(b)                       # 1 / ||I||_F

    def body(_, x):
        r = eye - jnp.einsum("...ab,...bc->...ac", m, x,
                             preferred_element_type=jnp.float32)
        res = jnp.sqrt(jnp.sum(r * r, axis=(-1, -2))) * rnorm
        step = x + jnp.einsum("...ab,...bc->...ac", x, r,
                              preferred_element_type=jnp.float32)
        return jnp.where((res > tol)[..., None, None], step, x)

    x = jax.lax.fori_loop(0, iters, body, x0)
    # residual of the returned iterate (the in-loop one lags by a step)
    r = eye - jnp.einsum("...ab,...bc->...ac", m, x,
                         preferred_element_type=jnp.float32)
    res = jnp.sqrt(jnp.sum(r * r, axis=(-1, -2))) * rnorm
    return x, res


def damped_factor_inverses(a: jax.Array, g: jax.Array, lam: float,
                           d_a: int, d_g: int, *, method: str = "eigh",
                           backend: Optional[str] = None,
                           ns_iters: int = NS_ITERS,
                           ns_tol: float = NS_TOL) -> tuple[jax.Array, jax.Array]:
    """Compute (A + pi*sqrt(lam) I)^-1 and (G + sqrt(lam)/pi I)^-1 (Eq. 12).

    Routes through :func:`repro.kernels.dispatch.damped_inverse` — the same
    signature the optimizer's Stage-4 recompute uses — so ``method``
    ("eigh" | "cholesky" | "newton_schulz") and ``backend`` select the
    implementation in exactly one place."""
    from repro.kernels import dispatch
    pi = pi_correction(a, g, d_a, d_g)
    sl = jnp.sqrt(jnp.asarray(lam, jnp.float32))
    kw = dict(method=method, backend=backend, ns_iters=ns_iters,
              ns_tol=ns_tol)
    a_inv = dispatch.damped_inverse(a, (pi * sl)[..., None], **kw)
    g_inv = dispatch.damped_inverse(g, (sl / pi)[..., None], **kw)
    return a_inv, g_inv


# ---------------------------------------------------------------------------
# Preconditioning
# ---------------------------------------------------------------------------

def precondition(dw: jax.Array, a_inv: Optional[jax.Array],
                 g_inv: Optional[jax.Array], *,
                 backend: Optional[str] = None) -> jax.Array:
    """Apply ``U = A^-1 @ dW @ G^-1`` with blocked inverses.

    dw: (..., d_in, d_out).
    a_inv: (..., nbA, bA, bA) or (..., d_in) diagonal or None.
    g_inv: (..., nbG, bG, bG) or (..., d_out) diagonal or None.
    ``backend`` routes the blocked applications through
    :mod:`repro.kernels.dispatch` (diagonal sides stay elementwise).
    """
    from repro.kernels import dispatch
    d_in, d_out = dw.shape[-2], dw.shape[-1]
    u = dw.astype(jnp.float32)
    if a_inv is not None:
        if a_inv.ndim == dw.ndim - 1:          # diagonal over d_in
            u = a_inv[..., :, None] * u
        else:
            ba = a_inv.shape[-1]
            ub = block_reshape(u, d_in, ba, axis=-2)   # (..., nbA, bA, d_out)
            ub = dispatch.block_precond_left(a_inv, ub, backend=backend)
            u = block_unreshape(ub, d_in, axis=-3)
    if g_inv is not None:
        if g_inv.ndim == dw.ndim - 1:          # diagonal over d_out
            u = u * g_inv[..., None, :]
        else:
            bg = g_inv.shape[-1]
            ub = block_reshape(u, d_out, bg, axis=-1)  # (..., d_in, nbG, bG)
            ub = dispatch.block_precond_right(ub, g_inv, backend=backend)
            u = block_unreshape(ub, d_out, axis=-2)
    return u.astype(dw.dtype)


# ---------------------------------------------------------------------------
# Unit-wise 2x2 inverse (Eq. 15-17) — used by scale/bias parameters
# ---------------------------------------------------------------------------

def unitwise_solve(stats: jax.Array, g_gamma: jax.Array, g_beta: jax.Array,
                   lam: float) -> tuple[jax.Array, jax.Array]:
    """Solve the per-channel 2x2 damped system (paper Eq. 16-17).

    stats: (..., C, 3) rows [E[gg], E[gb], E[bb]] per channel.
    g_gamma, g_beta: (..., C) gradients. Returns preconditioned grads.
    """
    aa = stats[..., 0] + lam
    ab = stats[..., 1]
    bb = stats[..., 2] + lam
    det = aa * bb - ab * ab
    det = jnp.where(det <= 1e-20, 1e-20, det)
    ug = (bb * g_gamma - ab * g_beta) / det
    ub = (-ab * g_gamma + aa * g_beta) / det
    return ug, ub


def diag_solve(stats: jax.Array, g: jax.Array, lam: float) -> jax.Array:
    """1x1 unit-wise (diagonal Fisher) solve: g / (E[g^2] + lam)."""
    return g / (stats + lam)


# ---------------------------------------------------------------------------
# Symmetry-aware packing (paper §5.2) — upper-triangular communication
# ---------------------------------------------------------------------------

def tril_indices(b: int) -> tuple[np.ndarray, np.ndarray]:
    return np.tril_indices(b)


def sym_pack(f: jax.Array) -> jax.Array:
    """Pack symmetric (..., b, b) into (..., b(b+1)/2)."""
    b = f.shape[-1]
    i, j = np.tril_indices(b)
    return f[..., i, j]


def sym_unpack(p: jax.Array, b: int) -> jax.Array:
    """Inverse of :func:`sym_pack`. A static GATHER, not a scatter: entry
    (r, c) reads packed position tri(max(r,c)) + min(r,c) — cheaper to
    lower, and exact for any dtype (incl. fp8 payloads) since no arithmetic
    touches the values."""
    r = np.arange(b)
    hi = np.maximum(r[:, None], r[None, :])
    lo = np.minimum(r[:, None], r[None, :])
    idx = (hi * (hi + 1)) // 2 + lo                      # (b, b) int
    f = jnp.take(p, jnp.asarray(idx.reshape(-1)), axis=-1)
    return f.reshape(p.shape[:-1] + (b, b))


# ---------------------------------------------------------------------------
# Frobenius similarity (Algorithm 2's predicate)
# ---------------------------------------------------------------------------

def frob_distance(x: jax.Array, y: jax.Array, eps: float = 1e-30) -> jax.Array:
    """||x - y||_F / ||y||_F, computed over ALL axes (a whole factor family
    is compared at once; DESIGN.md §"per-family refresh")."""
    num = jnp.sqrt(jnp.sum((x.astype(jnp.float32) - y.astype(jnp.float32)) ** 2))
    den = jnp.sqrt(jnp.sum(y.astype(jnp.float32) ** 2))
    return num / jnp.maximum(den, eps)
