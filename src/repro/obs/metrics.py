"""Per-step JSONL metrics stream with a near-zero-cost disabled path.

One :class:`MetricsLogger` owns all run-time telemetry output:

* the **JSONL event stream** (``--metrics-jsonl``): one JSON object per
  line, every event carrying ``{"v": schema version, "type": ..., "t_wall":
  unix time}``. Event types emitted by the launchers:

  - ``run_config``   — once at start: arch, flags, param count
  - ``step``         — per training step: loss, lr, refresh decisions,
                       grad/update norms, step-time EMA + p50/p99 from a
                       rolling window, the IntervalController's drained
                       byte-ledger deltas, NS/eigh inversion tallies.
                       Under the chunked refresh pipeline
                       (``--refresh-chunks K>1``) the ``kind`` field
                       distinguishes ``capture`` (refresh trigger, no
                       inline inversions) from ``refresh``/``fast``, and
                       ``refresh_inflight`` counts the steps until the
                       in-flight refresh activates: K+1 on the capture and
                       again on the first drain step (the capture does not
                       advance the chunk cursor), counting down to 1 on
                       the flip/activation step, 0 when idle
  - ``span``         — host-side phase timings (:class:`~repro.obs.tracing.Span`).
                       Pipeline drains additionally emit one
                       ``spngd.pipeline.chunk[i]`` span per chunk step
                       (``[flip]`` for the activation step) whose ``dur``
                       is the full fused step's wall time and whose
                       ``stats`` field lists the statistics the chunk
                       inverted — make_report derives the amortized
                       overlapped cost from these plus the fast-step dt
                       baseline
  - ``probe``        — the overhead-accounting probe (stage-isolated
                       timings the report's decomposition table consumes)
  - ``console``      — mirror of every console line
  - ``summary``      — once at end: the controller's flat counter totals
  - ``dryrun_case``  — one per dry-run record (launch.dryrun)

* the **console sink**: :meth:`console` prints byte-identically to the
  ``print()`` calls it replaced (log-scraping workflows keep working) and
  mirrors the line into the stream when enabled.

Disabled (no path/stream — the default), every emit method is a single
attribute check and return: no file is created, no event is built, and the
loss scalars the step events would force off-device are never fetched
(call sites gate those conversions on ``logger.enabled``). The
``obs.enabled_over_disabled`` benchmark row holds the enabled path to
<3% step-time overhead.

Loss values are written via ``json.dumps`` of the Python float, whose
repr round-trips bit-exactly — the stream's losses are bit-identical to
the returned step metrics (pinned by tests/test_obs.py).
"""

from __future__ import annotations

import collections
import json
import time
from typing import IO, Optional

from repro.obs.tracing import Span, SpanRecord

SCHEMA_VERSION = 1

_EMA_BETA = 0.9           # step-time EMA decay
_HIST_WINDOW = 256        # rolling window for p50/p99


class MetricsLogger:
    def __init__(self, path: Optional[str] = None,
                 stream: Optional[IO[str]] = None,
                 hist_window: int = _HIST_WINDOW):
        """``path`` opens (truncates) a JSONL file; ``stream`` writes to an
        existing file object (tests); neither = disabled."""
        if path is not None and stream is not None:
            raise ValueError("pass path or stream, not both")
        self.path = path
        self._own = path is not None
        self._stream = open(path, "w") if path is not None else stream
        self.enabled = self._stream is not None
        self.events_written = 0
        self._dts = collections.deque(maxlen=hist_window)
        self._ema: Optional[float] = None

    # ---- lifecycle ----

    def close(self) -> None:
        if self._stream is not None and self._own:
            self._stream.close()
            self._stream = None
            self.enabled = False

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ---- raw event emission ----

    def emit(self, type_: str, **fields) -> None:
        """Write one event line. No-op (one attribute check) when disabled."""
        if not self.enabled:
            return
        evt = {"v": SCHEMA_VERSION, "type": type_, "t_wall": time.time()}
        evt.update(fields)
        self._stream.write(json.dumps(evt) + "\n")
        self._stream.flush()
        self.events_written += 1

    # ---- console sink ----

    def console(self, text: str = "", *, flush: bool = True) -> None:
        """Print ``text`` exactly as the bare ``print()`` it replaces would
        have, and mirror it into the stream as a ``console`` event."""
        print(text, flush=flush)
        if self.enabled:
            self.emit("console", text=text)

    # ---- spans ----

    def span(self, name: str) -> Span:
        """A Span whose record lands in the stream (no sink when disabled,
        so the span costs two perf_counter calls and nothing else)."""
        return Span(name, sink=self._span_sink if self.enabled else None)

    def _span_sink(self, rec: SpanRecord) -> None:
        self.emit("span", name=rec.name, start=rec.start, dur=rec.dur,
                  depth=rec.depth, parent=rec.parent)

    # ---- the per-step event ----

    def log_step(self, step: int, *, loss: float, dt: Optional[float] = None,
                 **fields) -> None:
        """One ``step`` event. ``dt`` (seconds) feeds the rolling step-time
        EMA and p50/p99; extra keyword fields (lr, kind, refresh decisions,
        drained comm ledger, inversion tallies, norms) pass through as-is."""
        if not self.enabled:
            return
        evt = {"step": step, "loss": loss}
        if dt is not None:
            self._dts.append(dt)
            self._ema = (dt if self._ema is None
                         else _EMA_BETA * self._ema + (1 - _EMA_BETA) * dt)
            evt.update(dt=dt, dt_ema=self._ema, **self._quantiles())
        evt.update(fields)
        self.emit("step", **evt)

    def _quantiles(self) -> dict:
        srt = sorted(self._dts)
        n = len(srt)
        return {"dt_p50": srt[n // 2],
                "dt_p99": srt[min(n - 1, (99 * n) // 100)]}


# ---------------------------------------------------------------------------
# NS/eigh inversion tallies (the Stage-4 return_info consumer)
# ---------------------------------------------------------------------------

def inverse_tally(inverse_info: dict, block_sizes: dict) -> dict:
    """Fold the per-block ``{"ns_res", "ns_converged"}`` arrays that
    ``metrics["inverse_info"]`` carries (both Stage-4 call sites:
    ``ngd._damped_inv`` and ``comm.stage4.Stage4Inverter``) into JSON-ready
    per-statistic counters, keyed for a per-block-size rollup.

    ``ns_res < 0`` is the not-refreshed-this-step sentinel (the refresh
    cond's keep branch); those blocks are excluded from the tallies.
    ``fallback_blocks`` counts blocks that re-solved via eigh (residual
    above tol or SPD loss — the dispatch fallback contract); for the direct
    methods the residual is identically 0 so fallbacks are 0.
    """
    import numpy as np
    stats = {}
    by_b: dict = {}
    for name, info in inverse_info.items():
        res = np.asarray(info["ns_res"], dtype=np.float64).reshape(-1)
        conv = np.asarray(info["ns_converged"], dtype=bool).reshape(-1)
        refreshed = res >= 0.0
        n_ref = int(refreshed.sum())
        n_fb = int((~conv[refreshed]).sum()) if n_ref else 0
        b = int(block_sizes.get(name, 0))
        stats[name] = {
            "b": b,
            "blocks": int(res.size),
            "refreshed_blocks": n_ref,
            "fallback_blocks": n_fb,
            "max_res": float(res[refreshed].max()) if n_ref else 0.0,
        }
        if n_ref:
            agg = by_b.setdefault(b, {"refreshed_blocks": 0,
                                      "fallback_blocks": 0})
            agg["refreshed_blocks"] += n_ref
            agg["fallback_blocks"] += n_fb
    return {"stats": stats,
            "by_block_size": {str(b): v for b, v in sorted(by_b.items())}}
