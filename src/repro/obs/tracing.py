"""Stage-level tracing: host-side spans + device-trace annotations.

The paper's negligible-overhead claim (§5.2) is a *time-accounting* claim:
Stage-2 statistics construction, the Stage-3 ReduceScatterV and the Stage-4
inversions must disappear behind the forward/backward. This module gives
every SP-NGD stage a stable name in both timelines:

* :class:`Span` — a host-side phase timer (``time.perf_counter``) that also
  opens a ``jax.profiler.TraceAnnotation``, so the same phase shows up in a
  captured profiler trace. Spans nest; each records its depth and parent,
  which is what the metrics stream's ``span`` events carry.
* :func:`stage_scope` — ``jax.named_scope`` around *traced* code. Zero
  runtime cost (it only attaches HLO metadata at trace time) and it is what
  makes the four stages findable in a trace viewer regardless of how XLA
  fuses them. The canonical stage names are the ``STAGE_*`` constants —
  instrumentation sites must use them so traces stay comparable across PRs.
* :func:`kernel_scope` — the per-op/backend scope the kernel dispatch layer
  opens, so a ``ref`` vs ``pallas`` A/B of the same op lines up by name in
  the viewer (``repro.kernels.damped_inverse[pallas]`` vs ``[...ref]``).
* :class:`ProfileCapture` — the opt-in ``--profile-dir`` window: a real
  ``jax.profiler`` trace of the first N steps, started/stopped from the
  training loop.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

# Canonical scope names for the four SP-NGD stages (paper Fig. 2 / §5).
# Stage 1-2 (forward/backward + statistics capture) trace as one scope:
# capture rides the backward's saved activations, so they are one program
# region; the fast (no-capture) step simply never opens it.
STAGE_CAPTURE = "spngd.stage2.capture"     # grads + raw factor sums
STAGE_REDUCE = "spngd.stage3.reduce"       # factor ReduceScatterV
STAGE_INVERSE = "spngd.stage4.inverse"     # damped factor inversion
STAGE_GATHER = "spngd.stage4.gather"       # preconditioner all-gather
STAGE_PRECOND = "spngd.stage4.precond"     # A^-1 dW G^-1 apply
# Chunked refresh pipeline (repro.core.pipeline): one drain chunk fused
# into a fast step. STAGE_INVERSE / STAGE_GATHER nest under it, so trace
# filters on the stage-4 scopes keep working when the refresh is chunked.
STAGE_CHUNK = "spngd.pipeline.chunk"       # drain chunk inside a fast step


def stage_scope(name: str):
    """``jax.named_scope`` under the canonical stage name — free at runtime,
    names the region in HLO metadata / trace viewers."""
    return jax.named_scope(name)


def kernel_scope(op: str, which: str):
    """Stable trace-viewer name for one dispatched kernel op instance:
    ``repro.kernels.<op>[<backend>]``, so backend A/Bs line up by name."""
    return jax.named_scope(f"repro.kernels.{op}[{which}]")


@dataclasses.dataclass
class SpanRecord:
    """One finished span, as emitted to a sink (the metrics stream)."""
    name: str
    start: float          # perf_counter seconds (monotonic, process epoch)
    dur: float            # seconds
    depth: int            # nesting depth at entry (0 = top level)
    parent: Optional[str]  # enclosing span's name, None at top level


# Host-side span stack. The training/dryrun loops are single-threaded
# drivers, so a module-level stack is sufficient (and keeps Span allocation
# trivial); concurrent host threads would each want their own Tracer, which
# nothing here needs yet.
_ACTIVE: list["Span"] = []


class Span:
    """Host-side phase timer, nestable, with a profiler annotation.

    ``sink`` (a ``SpanRecord -> None`` callable, e.g.
    ``MetricsLogger._span_sink``) receives the record at exit; without a
    sink the span still times itself (``.dur``) for ad-hoc use. The
    ``TraceAnnotation`` makes the host phase visible in ``--profile-dir``
    captures; pass ``annotate=False`` to skip it (spans timed inside other
    profiler tooling).
    """

    def __init__(self, name: str,
                 sink: Optional[Callable[[SpanRecord], None]] = None,
                 annotate: bool = True):
        self.name = name
        self.sink = sink
        self.start = 0.0
        self.dur = 0.0
        self.depth = 0
        self.parent: Optional[str] = None
        self._ann = (jax.profiler.TraceAnnotation(name) if annotate
                     else None)

    def __enter__(self) -> "Span":
        self.depth = len(_ACTIVE)
        self.parent = _ACTIVE[-1].name if _ACTIVE else None
        _ACTIVE.append(self)
        if self._ann is not None:
            self._ann.__enter__()
        self.start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.dur = time.perf_counter() - self.start
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        _ACTIVE.pop()
        if self.sink is not None:
            self.sink(SpanRecord(self.name, self.start, self.dur,
                                 self.depth, self.parent))
        return False


class ProfileCapture:
    """Opt-in ``jax.profiler`` trace of the first N steps (--profile-dir).

    The loop calls :meth:`step_start` at the top of every iteration and
    :meth:`step_end` after the step's outputs are blocked on; the capture
    spans steps 1..N and stops itself. Inert when ``trace_dir`` is None,
    so call sites need no conditionals. :meth:`stop` is the end-of-run
    safety net for runs shorter than the window.
    """

    def __init__(self, trace_dir: Optional[str], steps: int = 3):
        self.trace_dir = trace_dir
        self.steps = max(1, steps)
        self._seen = 0
        self._active = False
        self.done = trace_dir is None

    def step_start(self, t: int) -> None:
        if self.done or self._active:
            return
        jax.profiler.start_trace(self.trace_dir)
        self._active = True

    def step_end(self, t: int) -> None:
        if not self._active:
            return
        self._seen += 1
        if self._seen >= self.steps:
            self.stop()

    def stop(self) -> None:
        if self._active:
            jax.profiler.stop_trace()
            self._active = False
        self.done = True
