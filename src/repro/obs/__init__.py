"""repro.obs — unified telemetry: stage tracing, metrics stream, reporting.

See :mod:`repro.obs.tracing` for the span/scope layer and
:mod:`repro.obs.metrics` for the JSONL event stream. The reporting layer
lives in ``experiments/make_report.py`` (overhead accounting) and
``benchmarks/kernels_bench.py`` (``obs.enabled_over_disabled`` gate).
"""

from repro.obs.tracing import (
    STAGE_CAPTURE,
    STAGE_CHUNK,
    STAGE_GATHER,
    STAGE_INVERSE,
    STAGE_PRECOND,
    STAGE_REDUCE,
    ProfileCapture,
    Span,
    SpanRecord,
    kernel_scope,
    stage_scope,
)
from repro.obs.metrics import SCHEMA_VERSION, MetricsLogger, inverse_tally

__all__ = [
    "STAGE_CAPTURE",
    "STAGE_CHUNK",
    "STAGE_GATHER",
    "STAGE_INVERSE",
    "STAGE_PRECOND",
    "STAGE_REDUCE",
    "ProfileCapture",
    "Span",
    "SpanRecord",
    "kernel_scope",
    "stage_scope",
    "SCHEMA_VERSION",
    "MetricsLogger",
    "inverse_tally",
]
