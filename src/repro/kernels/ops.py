"""Jitted public wrappers around the Pallas kernels.

On this container (CPU) the kernels execute in ``interpret=True`` mode; on a
real TPU set ``interpret=False`` (the default flips on backend detection).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import kfac_factor as _factor
from repro.kernels import kfac_precond as _precond
from repro.kernels import swa_attention as _swa


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def kfac_factor(x: jax.Array, *, bm: int = 256, bn: int = 256, bk: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """Symmetric factor A = X^T X (f32). The kernel fills only tiles with
    tile_i <= tile_j (symmetry-aware compute, DESIGN.md §6); this wrapper
    mirrors the strict-upper tiles and keeps diagonal tiles as computed."""
    assert bm == bn, "diagonal tiles require square tiling"
    interpret = _default_interpret() if interpret is None else interpret
    n, d = x.shape
    bt = min(bm, d)
    bkk = min(bk, n)
    dp = -(-d // bt) * bt
    np_ = -(-n // bkk) * bkk
    if dp != d or np_ != n:
        x = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    m = _factor.factor_syrk(x, bm=bt, bn=bt, bk=bkk, interpret=interpret)
    tr = jnp.arange(dp) // bt
    upper = jnp.where(tr[:, None] < tr[None, :], m, 0.0)
    diag = jnp.where(tr[:, None] == tr[None, :], m, 0.0)
    return (upper + upper.T + diag)[:d, :d]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def kfac_block_precond(binv: jax.Array, w: jax.Array, *, bm: int = 256,
                       bn: int = 256, bk: int = 256,
                       interpret: bool | None = None) -> jax.Array:
    """Blocked preconditioner application U[k] = Binv[k] @ W[k]."""
    interpret = _default_interpret() if interpret is None else interpret
    nb, b, _ = binv.shape
    m = w.shape[-1]
    bm_, bn_, bk_ = min(bm, b), min(bn, m), min(bk, b)
    # pad b to a multiple of BOTH tile sizes (their lcm): padding to
    # max(bm_, bk_) misaligns the grid when bm_ != bk_ and the smaller tile
    # doesn't divide the larger (the last tile then reads past the array)
    tile = math.lcm(bm_, bk_)
    bp = -(-b // tile) * tile
    mp = -(-m // bn_) * bn_
    if bp != b or mp != m:
        binv = jnp.pad(binv, ((0, 0), (0, bp - b), (0, bp - b)))
        w = jnp.pad(w, ((0, 0), (0, bp - b), (0, mp - m)))
    out = _precond.block_precond(binv, w, bm=bm_, bn=bn_, bk=bk_,
                                 interpret=interpret)
    return out[:, :b, :m]


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int = 0, bq: int = 256, bk: int = 256,
                  interpret: bool | None = None) -> jax.Array:
    """Causal sliding-window flash attention; (BH, S, hd) layout."""
    interpret = _default_interpret() if interpret is None else interpret
    bh, s, hd = q.shape
    bq_, bk_ = min(bq, s), min(bk, s)
    bt = math.lcm(bq_, bk_)          # same grid-alignment rule as above
    sp = -(-s // bt) * bt
    if sp != s:
        pad = ((0, 0), (0, sp - s), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    out = _swa.swa_flash(q, k, v, window=window, bq=bq_, bk=bk_,
                         interpret=interpret)
    return out[:, :s, :]
