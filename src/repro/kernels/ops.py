"""Jitted public wrappers around the Pallas kernels.

On this container (CPU) the kernels execute in ``interpret=True`` mode; on a
real TPU set ``interpret=False`` (the default flips on backend detection).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import kfac_factor as _factor
from repro.kernels import kfac_precond as _precond
from repro.kernels import swa_attention as _swa


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def kfac_factor(x: jax.Array, *, bm: int = 256, bn: int = 256, bk: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """Symmetric factor A = X^T X (f32). The kernel fills only tiles with
    tile_i <= tile_j (symmetry-aware compute, DESIGN.md §6); this wrapper
    mirrors the strict-upper tiles and keeps diagonal tiles as computed."""
    if bm != bn:
        raise ValueError(f"kfac_factor needs square tiling (diagonal tiles "
                         f"are mirrored in place); got bm={bm}, bn={bn}")
    interpret = _default_interpret() if interpret is None else interpret
    n, d = x.shape
    bt = min(bm, d)
    bkk = min(bk, n)
    dp = -(-d // bt) * bt
    np_ = -(-n // bkk) * bkk
    if dp != d or np_ != n:
        x = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    m = _factor.factor_syrk(x, bm=bt, bn=bt, bk=bkk, interpret=interpret)
    tr = jnp.arange(dp) // bt
    upper = jnp.where(tr[:, None] < tr[None, :], m, 0.0)
    diag = jnp.where(tr[:, None] == tr[None, :], m, 0.0)
    return (upper + upper.T + diag)[:d, :d]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def kfac_block_precond(binv: jax.Array, w: jax.Array, *, bm: int = 256,
                       bn: int = 256, bk: int = 256,
                       interpret: bool | None = None) -> jax.Array:
    """Blocked preconditioner application U[k] = Binv[k] @ W[k]."""
    interpret = _default_interpret() if interpret is None else interpret
    nb, b, _ = binv.shape
    m = w.shape[-1]
    bm_, bn_, bk_ = min(bm, b), min(bn, m), min(bk, b)
    # pad b to a multiple of BOTH tile sizes (their lcm): padding to
    # max(bm_, bk_) misaligns the grid when bm_ != bk_ and the smaller tile
    # doesn't divide the larger (the last tile then reads past the array)
    tile = math.lcm(bm_, bk_)
    bp = -(-b // tile) * tile
    mp = -(-m // bn_) * bn_
    if bp != b or mp != m:
        binv = jnp.pad(binv, ((0, 0), (0, bp - b), (0, bp - b)))
        w = jnp.pad(w, ((0, 0), (0, bp - b), (0, mp - m)))
    out = _precond.block_precond(binv, w, bm=bm_, bn=bn_, bk=bk_,
                                 interpret=interpret)
    return out[:, :b, :m]


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int = 0, bq: int = 256, bk: int = 256,
                  interpret: bool | None = None) -> jax.Array:
    """Causal sliding-window flash attention; (BH, S, hd) layout."""
    interpret = _default_interpret() if interpret is None else interpret
    bh, s, hd = q.shape
    bq_, bk_ = min(bq, s), min(bk, s)
    bt = math.lcm(bq_, bk_)          # same grid-alignment rule as above
    sp = -(-s // bt) * bt
    if sp != s:
        pad = ((0, 0), (0, sp - s), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    out = _swa.swa_flash(q, k, v, window=window, bq=bq_, bk=bk_,
                         interpret=interpret)
    return out[:, :s, :]


def _pad_seq(s: int, bq: int, bk: int) -> int:
    """Padded sequence length: a multiple of BOTH tile sizes (their lcm)."""
    tile = math.lcm(bq, bk)
    return -(-s // tile) * tile


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def swa_attention_fwd_res(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          window: int = 0, bq: int = 256, bk: int = 256,
                          interpret: bool | None = None):
    """Residual-saving training forward, GQA layout: q (BKV, G, S, hd),
    k/v (BKV, S, hd) — KV unexpanded, one kernel batch entry per KV head.
    Returns (out (BKV, G, S, hd), lse (BKV, G, S) f32)."""
    interpret = _default_interpret() if interpret is None else interpret
    bkv, g, s, hd = q.shape
    bq_, bk_ = min(bq, s), min(bk, s)
    sp = _pad_seq(s, bq_, bk_)
    if sp != s:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
        pad = ((0, 0), (0, sp - s), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    out, lse = _swa.swa_flash_fwd(q, k, v, window=window, bq=bq_, bk=bk_,
                                  interpret=interpret)
    return out[:, :, :s], lse[:, :, :s]


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def swa_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                      o: jax.Array, lse: jax.Array, do: jax.Array, *,
                      window: int = 0, bq: int = 256, bk: int = 256,
                      interpret: bool | None = None):
    """Fused backward from the saved (o, lse) residuals — no forward
    recompute. Layouts as in :func:`swa_attention_fwd_res`; returns
    (dq (BKV, G, S, hd), dk (BKV, S, hd), dv (BKV, S, hd)), all f32 with
    dk/dv accumulated per KV head across the query-head group."""
    interpret = _default_interpret() if interpret is None else interpret
    bkv, g, s, hd = q.shape
    bq_, bk_ = min(bq, s), min(bk, s)
    # D_i = rowsum(do * o) once on the XLA side (FlashAttention-2 style):
    # o then never enters the kernels' input streams, and the dk/dv sweep
    # doesn't re-derive it per visited tile
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    sp = _pad_seq(s, bq_, bk_)
    if sp != s:
        qpad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        kpad = ((0, 0), (0, sp - s), (0, 0))
        # NOTE the in-kernel k_pos < seq_len mask is vacuous here (the
        # kernels see the padded length): padded KEY columns are hidden
        # from real query rows by the causal mask alone (their positions
        # are > every real q_pos). Padded QUERY rows do see real keys with
        # p = exp(0 - 0) = 1, but contribute nothing because the zero-
        # padded do/delta force ds = 0 and p^T @ do = 0 — the zero padding
        # is load-bearing. The garbage dq rows are sliced off below.
        q, do = jnp.pad(q, qpad), jnp.pad(do, qpad)
        k, v = jnp.pad(k, kpad), jnp.pad(v, kpad)
        rpad = ((0, 0), (0, 0), (0, sp - s))
        lse, delta = jnp.pad(lse, rpad), jnp.pad(delta, rpad)
    dq = _swa.swa_flash_bwd_dq(q, k, v, lse, delta, do, window=window,
                               bq=bq_, bk=bk_, interpret=interpret)
    dk, dv = _swa.swa_flash_bwd_dkdv(q, k, v, lse, delta, do, window=window,
                                     bq=bq_, bk=bk_, interpret=interpret)
    return dq[:, :, :s], dk[:, :s], dv[:, :s]
