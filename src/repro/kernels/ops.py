"""Jitted public wrappers around the Pallas kernels.

On this container (CPU) the kernels execute in ``interpret=True`` mode; on a
real TPU set ``interpret=False`` (the default flips on backend detection).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import kfac_factor as _factor
from repro.kernels import kfac_precond as _precond
from repro.kernels import newton_schulz as _ns
from repro.kernels import quant_pack as _quant
from repro.kernels import swa_attention as _swa


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def kfac_factor(x: jax.Array, *, bm: int = 256, bn: int = 256, bk: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """Symmetric factor A = X^T X (f32). The kernel fills only tiles with
    tile_i <= tile_j (symmetry-aware compute, DESIGN.md §6); this wrapper
    mirrors the strict-upper tiles and keeps diagonal tiles as computed."""
    if bm != bn:
        raise ValueError(f"kfac_factor needs square tiling (diagonal tiles "
                         f"are mirrored in place); got bm={bm}, bn={bn}")
    interpret = _default_interpret() if interpret is None else interpret
    n, d = x.shape
    bt = min(bm, d)
    bkk = min(bk, n)
    dp = -(-d // bt) * bt
    np_ = -(-n // bkk) * bkk
    if dp != d or np_ != n:
        x = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    m = _factor.factor_syrk(x, bm=bt, bn=bt, bk=bkk, interpret=interpret)
    tr = jnp.arange(dp) // bt
    upper = jnp.where(tr[:, None] < tr[None, :], m, 0.0)
    diag = jnp.where(tr[:, None] == tr[None, :], m, 0.0)
    return (upper + upper.T + diag)[:d, :d]


# largest factor block the fused wire kernel keeps VMEM-resident: the f32
# scratch accumulator costs b^2 * 4 bytes plus the fp8 payload block and one
# (bk, b) input tile; 1024 -> ~5.7 MB against the ~16 MB/core budget.
# Dispatch routes bigger blocks to the ref path (XLA SYRK + quantize_rows).
FACTOR_WIRE_MAX_DIM = 1024


@functools.partial(jax.jit, static_argnames=("fmt", "scale_mode", "bk",
                                             "interpret"))
def kfac_factor_wire(x: jax.Array, *, fmt: str = "e4m3",
                     scale_mode: str = "fp32", bk: int = 512,
                     interpret: bool | None = None):
    """Fused factor construction + wire-format epilogue for ONE block:
    x (n, b) -> (payload (t,) fp8 sym-packed, scale () f32).

    The f32 factor sum exists only in the kernel's VMEM scratch; HBM
    receives the fp8 block + scale, and the sym-pack below is a static
    tril gather on 1-byte data (same row order as ``kfac.sym_pack``, so
    the emitted tile IS the PR-5 wire/storage tile)."""
    from repro.quant import quant as _q
    interpret = _default_interpret() if interpret is None else interpret
    n, b = x.shape
    if b > FACTOR_WIRE_MAX_DIM:
        raise ValueError(f"kfac_factor_wire holds the whole block in VMEM; "
                         f"b={b} exceeds FACTOR_WIRE_MAX_DIM="
                         f"{FACTOR_WIRE_MAX_DIM} (route to the ref path)")
    bp = -(-b // 128) * 128          # lane alignment; zeros are amax-neutral
    bkk = min(bk, n)
    npad = -(-n // bkk) * bkk
    if bp != b or npad != n:
        x = jnp.pad(x, ((0, npad - n), (0, bp - b)))
    payload, scale = _factor.factor_syrk_wire(
        x, _q.FORMATS[fmt], fmt_max=_q.FMT_MAX[fmt],
        pow2=(scale_mode == "pow2"), bk=bkk, interpret=interpret)
    i, j = np.tril_indices(b)
    return payload[:b, :b][i, j], scale[0, 0]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def kfac_block_precond(binv: jax.Array, w: jax.Array, *, bm: int = 256,
                       bn: int = 256, bk: int = 256,
                       interpret: bool | None = None) -> jax.Array:
    """Blocked preconditioner application U[k] = Binv[k] @ W[k]."""
    interpret = _default_interpret() if interpret is None else interpret
    nb, b, _ = binv.shape
    m = w.shape[-1]
    bm_, bn_, bk_ = min(bm, b), min(bn, m), min(bk, b)
    # pad b to a multiple of BOTH tile sizes (their lcm): padding to
    # max(bm_, bk_) misaligns the grid when bm_ != bk_ and the smaller tile
    # doesn't divide the larger (the last tile then reads past the array)
    tile = math.lcm(bm_, bk_)
    bp = -(-b // tile) * tile
    mp = -(-m // bn_) * bn_
    if bp != b or mp != m:
        binv = jnp.pad(binv, ((0, 0), (0, bp - b), (0, bp - b)))
        w = jnp.pad(w, ((0, 0), (0, bp - b), (0, mp - m)))
    out = _precond.block_precond(binv, w, bm=bm_, bn=bn_, bk=bk_,
                                 interpret=interpret)
    return out[:, :b, :m]


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int = 0, bq: int = 256, bk: int = 256,
                  interpret: bool | None = None) -> jax.Array:
    """Causal sliding-window flash attention; (BH, S, hd) layout."""
    interpret = _default_interpret() if interpret is None else interpret
    bh, s, hd = q.shape
    bq_, bk_ = min(bq, s), min(bk, s)
    bt = math.lcm(bq_, bk_)          # same grid-alignment rule as above
    sp = -(-s // bt) * bt
    if sp != s:
        pad = ((0, 0), (0, sp - s), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    out = _swa.swa_flash(q, k, v, window=window, bq=bq_, bk=bk_,
                         interpret=interpret)
    return out[:, :s, :]


def _pad_seq(s: int, bq: int, bk: int) -> int:
    """Padded sequence length: a multiple of BOTH tile sizes (their lcm)."""
    tile = math.lcm(bq, bk)
    return -(-s // tile) * tile


# largest factor block the Newton-Schulz kernel keeps VMEM-resident: one
# block costs ~3 * b^2 * 4 bytes (M, X, step temporary); 1024 -> ~12.6 MB
# against the ~16 MB/core budget. Dispatch routes bigger blocks to the
# two-level tiled variant (ns_inverse_tiled) below, which keeps the
# operands HBM-resident and streams (bt, bt) VMEM tiles per matmul.
NS_KERNEL_MAX_DIM = 1024


@functools.partial(jax.jit, static_argnames=("iters", "tol", "interpret"))
def ns_inverse(m: jax.Array, *, iters: int, tol: float,
               interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Blocked Newton-Schulz inverse of already-damped symmetric blocks.

    m: (g, b, b) f32 (``M = F + lambda I``, symmetrized by the caller) ->
    (x (g, b, b) f32 ~= M^-1, res (g,) f32 relative fixed-point residual
    ``||I - M x||_F / ||I||_F`` of the returned iterate).

    Blocks pad to the 128-lane boundary as ``[[M, 0], [0, dpad*I]]`` with
    ``dpad = ||M||_inf`` per block — an eigenvalue the iteration already
    has to cover (lambda_max <= ||M||_inf), so padding never slows the
    contraction the way a fixed pad value (e.g. 1) would for tiny- or
    huge-scaled factors. The padded rows/cols are sliced off below, and
    the kernel's residual (normalized by the PADDED ||I||_F) is rescaled
    back to the caller's b so the fallback decision matches the unpadded
    reference iteration instead of being sqrt(bp/b) looser.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if m.shape[-1] > NS_KERNEL_MAX_DIM:
        raise ValueError(f"ns_inverse holds whole blocks in VMEM; "
                         f"b={m.shape[-1]} exceeds NS_KERNEL_MAX_DIM="
                         f"{NS_KERNEL_MAX_DIM} (route to the ref iteration)")
    g, b, _ = m.shape
    bp = -(-b // 128) * 128
    if bp != b:
        dpad = jnp.maximum(jnp.max(jnp.sum(jnp.abs(m), axis=-1), axis=-1),
                           jnp.float32(1e-30))           # (g,): ||M||_inf
        m = jnp.pad(m, ((0, 0), (0, bp - b), (0, bp - b)))
        pad_diag = jnp.where(jnp.arange(bp) >= b, 1.0, 0.0)
        m = m + dpad[:, None, None] * jnp.diag(pad_diag)
    # the kernel normalizes by the PADDED 1/||I_bp||_F: hand it the
    # equivalently-rescaled freeze threshold and scale the residual back,
    # so both the early exit and the fallback decision match the unpadded
    # reference iteration exactly (the padded identity's own residual
    # rides along, erring toward the eigh fallback)
    scale = math.sqrt(bp / b)
    x, res = _ns.ns_inverse_blocks(m, iters=iters, tol=tol / scale,
                                   interpret=interpret)
    return x[:, :b, :b], res[:, 0] * scale


def _ns_tile(bp: int) -> int:
    """Largest MXU-aligned tile that divides the padded block dim (so the
    tile grid needs no edge masking); bp is always a multiple of 128."""
    for bt in (512, 384, 256, 128):
        if bp % bt == 0:
            return bt
    return 128


@functools.partial(jax.jit, static_argnames=("iters", "tol", "interpret"))
def ns_inverse_tiled(m: jax.Array, *, iters: int, tol: float,
                     interpret: bool | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Two-level tiled Newton-Schulz inverse for blocks past
    :data:`NS_KERNEL_MAX_DIM` — same contract as :func:`ns_inverse`
    (already-damped symmetric (g, b, b) blocks in, (inverse, per-block
    residual) out) with no VMEM cap on b.

    Level 1 (here): the iteration's step sequencing — a ``fori_loop``
    whose body calls one residual kernel (``R = I - M X`` + ||R||_F^2)
    and one update kernel (``X' = X + X R``) per trip, freezing converged
    blocks exactly like the resident kernel does. Level 2 (the kernels):
    each matmul walks a (bt, bt) VMEM tile grid over the HBM-resident
    operands. Padding/rescale rules are identical to :func:`ns_inverse`
    (``dpad = ||M||_inf`` identity padding, residual rescaled to the
    unpadded ||I_b||_F), except blocks pad to the tile size so the grid
    needs no edge masking.
    """
    interpret = _default_interpret() if interpret is None else interpret
    g, b, _ = m.shape
    bt = _ns_tile(-(-b // 128) * 128)
    bp = -(-b // bt) * bt
    if bp != b:
        dpad = jnp.maximum(jnp.max(jnp.sum(jnp.abs(m), axis=-1), axis=-1),
                           jnp.float32(1e-30))           # (g,): ||M||_inf
        m = jnp.pad(m, ((0, 0), (0, bp - b), (0, bp - b)))
        pad_diag = jnp.where(jnp.arange(bp) >= b, 1.0, 0.0)
        m = m + dpad[:, None, None] * jnp.diag(pad_diag)
    scale = math.sqrt(bp / b)
    tol_p = tol / scale
    rnorm = 1.0 / math.sqrt(bp)
    am = jnp.abs(m)
    n1 = jnp.max(jnp.sum(am, axis=-2), axis=-1)          # (g,)
    ninf = jnp.max(jnp.sum(am, axis=-1), axis=-1)
    x0 = m * (1.0 / (n1 * ninf))[:, None, None]

    def resid(x):
        r, ss = _ns.ns_tiled_residual(m, x, bt=bt, interpret=interpret)
        return r, jnp.sqrt(ss[:, 0, 0]) * rnorm

    def body(_, x):
        r, res = resid(x)
        xn = _ns.ns_tiled_update(x, r, bt=bt, interpret=interpret)
        return jnp.where((res > tol_p)[:, None, None], xn, x)

    x = jax.lax.fori_loop(0, iters, body, x0)
    _, res = resid(x)                # residual of the RETURNED iterate
    return x[:, :b, :b], res * scale


# VMEM budget for one quantization tile, in ELEMENTS of the packed row
# axis: a tile touches ~5 bytes/element (f32 in + fp8 out), so 2^21
# elements ≈ 10.5 MB — one whole row of the largest factor block the
# framework produces (max_dim=2048 -> t = b(b+1)/2 ≈ 2.1M) still fits the
# ~16 MB/core VMEM with bg=1, and smaller rows batch up to bg per tile.
_QUANT_TILE_ELEMS = 1 << 21


def _rows_per_tile(bg: int, g: int, t: int) -> int:
    return max(1, min(bg, g, _QUANT_TILE_ELEMS // max(t, 1)))


@functools.partial(jax.jit, static_argnames=("fmt", "scale_mode", "bg",
                                             "interpret"))
def fp8_quant_rows(x: jax.Array, *, fmt: str = "e4m3",
                   scale_mode: str = "fp32", bg: int = 8,
                   interpret: bool | None = None):
    """Per-row fp8 quantization: (..., t) -> (payload fp8 (..., t),
    scale f32 (...,)). Rows are whole quantization tiles (one scale each);
    for sym-packed factors a row is one block's packed lower triangle."""
    from repro.quant import quant as _q
    interpret = _default_interpret() if interpret is None else interpret
    lead, t = x.shape[:-1], x.shape[-1]
    flat = x.reshape((-1, t))
    g = flat.shape[0]
    bg_ = _rows_per_tile(bg, g, t)
    gp = -(-g // bg_) * bg_
    tp = -(-t // 128) * 128          # lane alignment; zeros are amax-neutral
    if gp != g or tp != t:
        flat = jnp.pad(flat, ((0, gp - g), (0, tp - t)))
    payload, scale = _quant.quant_rows(
        flat, _q.FORMATS[fmt], fmt_max=_q.FMT_MAX[fmt],
        pow2=(scale_mode == "pow2"), bg=bg_, interpret=interpret)
    return (payload[:g, :t].reshape(lead + (t,)),
            scale[:g, 0].reshape(lead))


@functools.partial(jax.jit, static_argnames=("bg", "interpret"))
def fp8_dequant_rows(payload: jax.Array, scale: jax.Array, *, bg: int = 8,
                     interpret: bool | None = None) -> jax.Array:
    """Inverse of :func:`fp8_quant_rows`: fp8 payload + per-row scale -> f32."""
    interpret = _default_interpret() if interpret is None else interpret
    lead, t = payload.shape[:-1], payload.shape[-1]
    flat = payload.reshape((-1, t))
    g = flat.shape[0]
    bg_ = _rows_per_tile(bg, g, t)
    gp = -(-g // bg_) * bg_
    tp = -(-t // 128) * 128
    if gp != g or tp != t:
        flat = jnp.pad(flat, ((0, gp - g), (0, tp - t)))
    s = jnp.pad(scale.reshape((-1, 1)).astype(jnp.float32),
                ((0, gp - g), (0, 0)))
    out = _quant.dequant_rows(flat, s, bg=bg_, interpret=interpret)
    return out[:g, :t].reshape(lead + (t,))


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def swa_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array,
               *, window: int = 0, k_scale: jax.Array | None = None,
               v_scale: jax.Array | None = None, bk: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Single-query flash decode over a KV cache (serving hot path).

    q (N, G, hd) — one query token per sequence in the GQA kernel layout
    (N = B * KV heads, G query heads per KV head); k/v (N, C, hd) cache
    payload (f32/bf16 dense or fp8 with ``k_scale``/``v_scale`` (N, C) f32
    per-row dequant scales); pos (N,) i32 absolute query positions.
    ``window > 0`` means C == window and the cache is a RING buffer (token
    at position p lives in slot p % window); ``window == 0`` attends the
    dense cache full-causally. Returns (N, G, hd) f32."""
    interpret = _default_interpret() if interpret is None else interpret
    n, g, hd = q.shape
    c = k.shape[1]
    if window and c != window:
        raise ValueError(f"ring decode needs k.shape[1] == window; got "
                         f"{c} vs {window}")
    if k_scale is None:
        k_scale = jnp.ones((n, c), jnp.float32)
    if v_scale is None:
        v_scale = jnp.ones((n, c), jnp.float32)
    bk_ = min(bk, -(-c // 128) * 128)
    cp = -(-c // bk_) * bk_
    if cp != c:
        # zero-fill padding: masked off in-kernel via slot < C
        k = jnp.pad(k, ((0, 0), (0, cp - c), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, cp - c), (0, 0)))
        k_scale = jnp.pad(k_scale, ((0, 0), (0, cp - c)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, cp - c)))
    # k/v enter the kernel in their STORED dtype (fp8 payloads included) —
    # the dequant (cast + scale multiply) happens on read in VMEM, so the
    # f32 cache never exists in HBM
    return _swa.swa_flash_decode(
        q, k, v, k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
        pos.astype(jnp.int32).reshape(n, 1), window=window, cache_len=c,
        bk=bk_, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def swa_attention_fwd_res(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          window: int = 0, bq: int = 256, bk: int = 256,
                          interpret: bool | None = None):
    """Residual-saving training forward, GQA layout: q (BKV, G, S, hd),
    k/v (BKV, S, hd) — KV unexpanded, one kernel batch entry per KV head.
    Returns (out (BKV, G, S, hd), lse (BKV, G, S) f32)."""
    interpret = _default_interpret() if interpret is None else interpret
    bkv, g, s, hd = q.shape
    bq_, bk_ = min(bq, s), min(bk, s)
    sp = _pad_seq(s, bq_, bk_)
    if sp != s:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
        pad = ((0, 0), (0, sp - s), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    out, lse = _swa.swa_flash_fwd(q, k, v, window=window, bq=bq_, bk=bk_,
                                  interpret=interpret)
    return out[:, :, :s], lse[:, :, :s]


@functools.partial(jax.jit, static_argnames=("window", "bq", "bk",
                                             "interpret"))
def swa_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                      o: jax.Array, lse: jax.Array, do: jax.Array, *,
                      window: int = 0, bq: int = 256, bk: int = 256,
                      interpret: bool | None = None):
    """Fused backward from the saved (o, lse) residuals — no forward
    recompute. Layouts as in :func:`swa_attention_fwd_res`; returns
    (dq (BKV, G, S, hd), dk (BKV, S, hd), dv (BKV, S, hd)), all f32 with
    dk/dv accumulated per KV head across the query-head group."""
    interpret = _default_interpret() if interpret is None else interpret
    bkv, g, s, hd = q.shape
    bq_, bk_ = min(bq, s), min(bk, s)
    # D_i = rowsum(do * o) once on the XLA side (FlashAttention-2 style):
    # o then never enters the kernels' input streams, and the dk/dv sweep
    # doesn't re-derive it per visited tile
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    sp = _pad_seq(s, bq_, bk_)
    if sp != s:
        qpad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        kpad = ((0, 0), (0, sp - s), (0, 0))
        # NOTE the in-kernel k_pos < seq_len mask is vacuous here (the
        # kernels see the padded length): padded KEY columns are hidden
        # from real query rows by the causal mask alone (their positions
        # are > every real q_pos). Padded QUERY rows do see real keys with
        # p = exp(0 - 0) = 1, but contribute nothing because the zero-
        # padded do/delta force ds = 0 and p^T @ do = 0 — the zero padding
        # is load-bearing. The garbage dq rows are sliced off below.
        q, do = jnp.pad(q, qpad), jnp.pad(do, qpad)
        k, v = jnp.pad(k, kpad), jnp.pad(v, kpad)
        rpad = ((0, 0), (0, 0), (0, sp - s))
        lse, delta = jnp.pad(lse, rpad), jnp.pad(delta, rpad)
    dq = _swa.swa_flash_bwd_dq(q, k, v, lse, delta, do, window=window,
                               bq=bq_, bk=bk_, interpret=interpret)
    dk, dv = _swa.swa_flash_bwd_dkdv(q, k, v, lse, delta, do, window=window,
                                     bq=bq_, bk=bk_, interpret=interpret)
    return dq[:, :, :s], dk[:, :s], dv[:, :s]
