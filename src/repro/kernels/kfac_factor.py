"""Pallas TPU kernel: symmetric rank-k factor construction  A = X^T X.

This is the paper's statistics-construction hot-spot (§5.2 "the first
hotspot is the construction of the statistics A, G") mapped to the TPU:

* MXU-aligned (multiples of 128) VMEM tiles;
* f32 accumulation from bf16 inputs (the paper's mixed-precision Tensor-Core
  factor computation, §5.2);
* symmetry-aware *compute*: only output tiles with i <= j are computed
  (``pl.when`` guard); the wrapper mirrors the strict upper triangle. This
  is the TPU analogue of the paper's symmetry-aware communication — applied
  one level earlier, to the FLOPs themselves (~2x tile savings).

Grid: (d/bm, d/bn, n/bk); the k axis accumulates into the (i, j) output
tile, which Pallas keeps resident in VMEM across the k sweep (output revisit
ordering), so each tile is written to HBM exactly once.

``factor_syrk_wire`` is the fused wire-format variant (Stage-3 "fused"
strategy): the SYRK accumulates into a f32 VMEM scratch block, and the final
k step runs the :mod:`repro.kernels.quant_pack` epilogue in place — block
amax, per-block scale, clip, fp8 cast — so the ONLY HBM writes are the fp8
payload and one f32 scale. The raw f32 factor sum never round-trips HBM
before the collective.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _factor_kernel(x_i_ref, x_j_ref, out_ref, *, n_k: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(i <= j)
    def _accum():
        xi = x_i_ref[...].astype(jnp.float32)      # (bk, bm)
        xj = x_j_ref[...].astype(jnp.float32)      # (bk, bn)
        out_ref[...] += jax.lax.dot_general(
            xi, xj, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def factor_syrk(x: jax.Array, *, bm: int = 256, bn: int = 256,
                bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: (n, d) -> lower-triangle-valid (d, d) f32 partial result.

    Tiles with i > j are left zero; use ``ops.kfac_factor`` for the
    mirrored symmetric result.
    """
    n, d = x.shape
    bm = min(bm, d)
    bn = min(bn, d)
    bk = min(bk, n)
    grid = (pl.cdiv(d, bm), pl.cdiv(d, bn), pl.cdiv(n, bk))

    return pl.pallas_call(
        functools.partial(_factor_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(x, x)


def _factor_wire_kernel(x_ref, payload_ref, scale_ref, acc_ref, *,
                        n_k: int, fmt_max: float, pow2: bool):
    """SYRK accumulate in VMEM scratch; quantize epilogue on the last k.

    The epilogue is byte-for-byte the :mod:`quant_pack` math (explicit
    reciprocal-multiply scale, pow2 rounding, clip before the fp8 cast) with
    ONE scale for the whole (b, b) block — the same granularity as one
    sym-packed row, so the emitted tile is the PR-5 wire/storage tile.
    """
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xk = x_ref[...].astype(jnp.float32)                  # (bk, b)
    acc_ref[...] += jax.lax.dot_general(
        xk, xk, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        f = acc_ref[...]                                 # (b, b) f32
        amax = jnp.max(jnp.abs(f))
        s = amax * (1.0 / fmt_max)
        if pow2:
            s = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(s, 2.0 ** -126))))
        s = jnp.where(amax > 0, s, 1.0)
        scale_ref[0, 0] = s
        q = jnp.clip(f / s, -fmt_max, fmt_max)   # e4m3fn overflows to NaN
        payload_ref[...] = q.astype(payload_ref.dtype)


def factor_syrk_wire(x: jax.Array, fp8_dtype, *, fmt_max: float,
                     pow2: bool = False, bk: int = 512,
                     interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (n, b) -> (payload (b, b) fp8, scale (1, 1) f32).

    Single-block fused SYRK -> wire-format epilogue: the f32 accumulator
    lives only in VMEM scratch across the k sweep; the last grid step
    quantizes it in place. The full (b, b) fp8 block is emitted (symmetric
    by construction); the wrapper's XLA-side ``sym_pack`` gather on the
    1-byte payload produces the packed triangle — pure byte movement, the
    same division of labour as the quant_pack wrappers.
    """
    n, b = x.shape
    bkk = min(bk, n)
    grid = (pl.cdiv(n, bkk),)
    return pl.pallas_call(
        functools.partial(_factor_wire_kernel, n_k=grid[0],
                          fmt_max=fmt_max, pow2=pow2),
        grid=grid,
        in_specs=[pl.BlockSpec((bkk, b), lambda k: (k, 0))],
        out_specs=[
            pl.BlockSpec((b, b), lambda k: (0, 0)),
            pl.BlockSpec((1, 1), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, b), fp8_dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((b, b), jnp.float32)],
        interpret=interpret,
    )(x)
