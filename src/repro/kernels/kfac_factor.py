"""Pallas TPU kernel: symmetric rank-k factor construction  A = X^T X.

This is the paper's statistics-construction hot-spot (§5.2 "the first
hotspot is the construction of the statistics A, G") mapped to the TPU:

* MXU-aligned (multiples of 128) VMEM tiles;
* f32 accumulation from bf16 inputs (the paper's mixed-precision Tensor-Core
  factor computation, §5.2);
* symmetry-aware *compute*: only output tiles with i <= j are computed
  (``pl.when`` guard); the wrapper mirrors the strict upper triangle. This
  is the TPU analogue of the paper's symmetry-aware communication — applied
  one level earlier, to the FLOPs themselves (~2x tile savings).

Grid: (d/bm, d/bn, n/bk); the k axis accumulates into the (i, j) output
tile, which Pallas keeps resident in VMEM across the k sweep (output revisit
ordering), so each tile is written to HBM exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _factor_kernel(x_i_ref, x_j_ref, out_ref, *, n_k: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(i <= j)
    def _accum():
        xi = x_i_ref[...].astype(jnp.float32)      # (bk, bm)
        xj = x_j_ref[...].astype(jnp.float32)      # (bk, bn)
        out_ref[...] += jax.lax.dot_general(
            xi, xj, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def factor_syrk(x: jax.Array, *, bm: int = 256, bn: int = 256,
                bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: (n, d) -> lower-triangle-valid (d, d) f32 partial result.

    Tiles with i > j are left zero; use ``ops.kfac_factor`` for the
    mirrored symmetric result.
    """
    n, d = x.shape
    bm = min(bm, d)
    bn = min(bn, d)
    bk = min(bk, n)
    grid = (pl.cdiv(d, bm), pl.cdiv(d, bn), pl.cdiv(n, bk))

    return pl.pallas_call(
        functools.partial(_factor_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(x, x)
