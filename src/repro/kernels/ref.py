"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kfac_factor_ref(x: jax.Array) -> jax.Array:
    """A = X^T X in f32. x: (n, d) -> (d, d)."""
    xf = x.astype(jnp.float32)
    return xf.T @ xf


def block_precond_ref(binv: jax.Array, w: jax.Array) -> jax.Array:
    """U[k] = Binv[k] @ W[k]. (nb,b,b),(nb,b,m) -> (nb,b,m) f32."""
    return jnp.einsum("kab,kbm->kam", binv.astype(jnp.float32),
                      w.astype(jnp.float32))


def swa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      window: int = 0) -> jax.Array:
    """Causal (+ sliding window) attention. q,k,v: (BH, S, hd)."""
    bh, s, hd = q.shape
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = kp <= qp
    if window:
        mask &= kp > (qp - window)
    scores = jnp.where(mask[None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def swa_attention_fwd_res_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                              window: int = 0):
    """GQA training forward with residuals, materialized scores.
    q: (BKV, G, S, hd); k, v: (BKV, S, hd) — KV per head, unexpanded.
    Returns (out (BKV, G, S, hd), lse (BKV, G, S) f32)."""
    bkv, g, s, hd = q.shape
    scores = jnp.einsum("bgqd,bkd->bgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = kp <= qp
    if window:
        mask &= kp > (qp - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    m = scores.max(-1)
    p = jnp.exp(scores - m[..., None])
    denom = p.sum(-1)
    lse = m + jnp.log(denom)
    out = jnp.einsum("bgqk,bkd->bgqd", p,
                     v.astype(jnp.float32)) / denom[..., None]
    return out.astype(q.dtype), lse
