"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kfac_factor_ref(x: jax.Array) -> jax.Array:
    """A = X^T X in f32. x: (n, d) -> (d, d)."""
    xf = x.astype(jnp.float32)
    return xf.T @ xf


def block_precond_ref(binv: jax.Array, w: jax.Array) -> jax.Array:
    """U[k] = Binv[k] @ W[k]. (nb,b,b),(nb,b,m) -> (nb,b,m) f32."""
    return jnp.einsum("kab,kbm->kam", binv.astype(jnp.float32),
                      w.astype(jnp.float32))


def swa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      window: int = 0) -> jax.Array:
    """Causal (+ sliding window) attention. q,k,v: (BH, S, hd)."""
    bh, s, hd = q.shape
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = kp <= qp
    if window:
        mask &= kp > (qp - window)
    scores = jnp.where(mask[None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def swa_decode_slot_positions(pos: jax.Array, capacity: int) -> jax.Array:
    """Absolute position held by each ring slot after the token at ``pos``
    was written (slot = position % capacity).

    pos: (N,) i32 current decode position(s); returns (N, capacity) i32 where
    entry s is the position of the token resident in slot s: the most recent
    position p <= pos with p % capacity == s. Slots not yet written (pos + 1
    < capacity) come out NEGATIVE — the caller masks on ``>= 0``. This is the
    single source of the ring<->position contract shared by the jnp oracle
    and the Pallas decode kernel's in-kernel index math.
    """
    sl = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    r = (pos[:, None] % capacity).astype(jnp.int32)
    base = pos[:, None].astype(jnp.int32) - r
    return jnp.where(sl <= r, base + sl, base - capacity + sl)


def swa_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                   pos: jax.Array, *, window: int = 0,
                   k_scale: jax.Array = None, v_scale: jax.Array = None
                   ) -> jax.Array:
    """Single-query decode attention oracle (materialized scores).

    q: (N, G, hd) — one query token per sequence, G query heads per KV head
    (GQA layout, N = B * KV). k/v: (N, C, hd) — the KV cache contents:
    ``window > 0`` means C == window and k/v are a RING buffer (token at
    position p lives in slot p % window); ``window == 0`` means a dense
    cache attended full-causally (slot s holds position s). pos: (N,) i32
    absolute position of the query (== number of previously cached tokens);
    the query's own k/v must already be written. k_scale/v_scale: (N, C)
    per-row dequant scales for fp8 payloads (None = dense, no dequant).
    Returns (N, G, hd) in q.dtype. Visibility contract (pinned by
    tests/test_serve_decode.py): key position j is visible iff
    ``0 <= j <= pos`` and, when window > 0, ``j > pos - window`` — i.e.
    exactly ``min(pos + 1, window)`` keys.
    """
    n, c, hd = k.shape
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[..., None].astype(jnp.float32)
    if v_scale is not None:
        vf = vf * v_scale[..., None].astype(jnp.float32)
    s = jnp.einsum("ngd,ncd->ngc", q.astype(jnp.float32) * hd ** -0.5, kf)
    posb = pos[:, None].astype(jnp.int32)
    if window:
        if c != window:
            raise ValueError(f"ring decode needs k.shape[1] == window; got "
                             f"{c} vs {window}")
        p = swa_decode_slot_positions(pos, c)
        valid = (p >= 0) & (p <= posb) & (p > posb - window)
    else:
        p = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[None, :], (n, c))
        valid = p <= posb
    s = jnp.where(valid[:, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("ngc,ncd->ngd", w, vf).astype(q.dtype)


def swa_attention_fwd_res_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                              window: int = 0):
    """GQA training forward with residuals, materialized scores.
    q: (BKV, G, S, hd); k, v: (BKV, S, hd) — KV per head, unexpanded.
    Returns (out (BKV, G, S, hd), lse (BKV, G, S) f32)."""
    bkv, g, s, hd = q.shape
    scores = jnp.einsum("bgqd,bkd->bgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = kp <= qp
    if window:
        mask &= kp > (qp - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    m = scores.max(-1)
    p = jnp.exp(scores - m[..., None])
    denom = p.sum(-1)
    lse = m + jnp.log(denom)
    out = jnp.einsum("bgqk,bkd->bgqd", p,
                     v.astype(jnp.float32)) / denom[..., None]
    return out.astype(q.dtype), lse
