"""Pallas TPU kernel: sliding-window flash attention (causal, GQA-ready).

Used by the long-context decode configs (long_500k) and Mixtral-style SWA.
Online-softmax over KV tiles; out-of-window tiles are skipped via ``pl.when``
so the compute is O(S * W) not O(S^2). Scratch (VMEM) carries the running
(max, denom, accumulator) across the KV sweep for each query tile.

Layout: q (BH, S, hd), k/v (BH, S, hd) — heads pre-flattened into the batch
dim (GQA repeat happens in ops.py). Grid: (BH, S/bq, S/bk) with the KV axis
innermost (accumulation axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, d_ref, acc_ref, *,
                bq: int, bk: int, window: int, n_k: int, seq_len: int,
                scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile visibility: query rows [qi*bq, qi*bq+bq), keys [kj*bk, kj*bk+bk)
    # causal: k <= q;  window: k > q - window
    q_lo = qi * bq
    q_hi = q_lo + bq - 1
    k_lo = kj * bk
    k_hi = k_lo + bk - 1
    in_range = (k_lo <= q_hi)
    if window:
        # a key tile matters iff it intersects the band (q-window, q] for
        # ANY query in the tile: k_hi > q_lo - window
        in_range = jnp.logical_and(in_range, k_hi > q_lo - window)

    @pl.when(in_range)
    def _tile():
        q = q_ref[0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (k_pos <= q_pos) & (k_pos < seq_len)
        if window:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                            # (bq,)
        m_new = jnp.maximum(m_prev, s.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        d_ref[...] = d_ref[...] * corr + p.sum(-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        denom = jnp.maximum(d_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)[None]


def swa_flash(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int = 0,
              bq: int = 256, bk: int = 256,
              interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, hd) -> (BH, S, hd); causal (+ optional window)."""
    bh, s, hd = q.shape
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    n_k = pl.cdiv(s, bk_)
    grid = (bh, pl.cdiv(s, bq_), n_k)
    scale = hd ** -0.5

    return pl.pallas_call(
        functools.partial(_swa_kernel, bq=bq_, bk=bk_, window=window,
                          n_k=n_k, seq_len=s, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),        # running max
            pltpu.VMEM((bq_,), jnp.float32),        # running denominator
            pltpu.VMEM((bq_, hd), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
