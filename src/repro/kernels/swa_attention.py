"""Pallas TPU kernels: sliding-window flash attention (causal, GQA-aware),
forward and fused backward.

Used by the long-context decode configs (long_500k) and Mixtral-style SWA.
Online-softmax over KV tiles; out-of-window tiles are skipped via ``pl.when``
so the compute is O(S * W) not O(S^2) — in the backward kernels too. Scratch
(VMEM) carries the running (max, denom, accumulator) across the KV sweep for
each query tile, and the (dk, dv) accumulators across the (group, Q) sweep
for each KV tile.

Two layouts:

* ``swa_flash`` — q/k/v ``(BH, S, hd)``, heads pre-flattened into the batch
  dim (GQA repeat happens in the caller). Forward only; kept for the plain
  ``swa_attention`` dispatch op.
* ``swa_flash_fwd`` / ``swa_flash_bwd_dq`` / ``swa_flash_bwd_dkdv`` — the
  training path. GQA-grouped: q/do/o ``(BKV, G, S, hd)`` (G = query heads
  per KV head), k/v ``(BKV, S, hd)`` — KV is handed to the kernel
  *unexpanded*, so kernel bandwidth does not inflate by ``h/kv`` and dk/dv
  come out accumulated per KV head. The forward also emits the per-row
  logsumexp ``lse = m + log(sum exp(s - m))`` residual the fused backward
  needs to rebuild the probabilities without a second online-softmax pass.

Grids put the accumulation axis innermost: forward/dq ``(BKV, G, S/bq,
S/bk)``; dk/dv ``(BKV, S/bk, G, S/bq)`` (each KV tile accumulates over every
query-head in its group and every visible Q tile before writing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, d_ref, acc_ref, *,
                bq: int, bk: int, window: int, n_k: int, seq_len: int,
                scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(_tile_in_range(qi, kj, bq=bq, bk=bk, window=window))
    def _tile():
        q = q_ref[0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _tile_mask(qi, kj, bq=bq, bk=bk, window=window,
                          seq_len=seq_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                            # (bq,)
        m_new = jnp.maximum(m_prev, s.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        d_ref[...] = d_ref[...] * corr + p.sum(-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        denom = jnp.maximum(d_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)[None]


def _tile_in_range(qi, kj, *, bq: int, bk: int, window: int):
    """Does KV tile kj intersect the visible band of Q tile qi?
    causal: k <= q for some (q, k) in the tile pair; window: k > q - window
    for the tile's largest q."""
    q_lo = qi * bq
    q_hi = q_lo + bq - 1
    k_lo = kj * bk
    k_hi = k_lo + bk - 1
    in_range = (k_lo <= q_hi)
    if window:
        in_range = jnp.logical_and(in_range, k_hi > q_lo - window)
    return in_range


def _tile_mask(qi, kj, *, bq: int, bk: int, window: int, seq_len: int):
    """Per-element (bq, bk) visibility mask for the (qi, kj) tile pair."""
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (k_pos <= q_pos) & (k_pos < seq_len)
    if window:
        mask &= k_pos > (q_pos - window)
    return mask


def swa_flash(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int = 0,
              bq: int = 256, bk: int = 256,
              interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, hd) -> (BH, S, hd); causal (+ optional window)."""
    bh, s, hd = q.shape
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    n_k = pl.cdiv(s, bk_)
    grid = (bh, pl.cdiv(s, bq_), n_k)
    scale = hd ** -0.5

    return pl.pallas_call(
        functools.partial(_swa_kernel, bq=bq_, bk=bk_, window=window,
                          n_k=n_k, seq_len=s, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),        # running max
            pltpu.VMEM((bq_,), jnp.float32),        # running denominator
            pltpu.VMEM((bq_, hd), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# serving path: single-query flash decode over a (ring-buffer) KV cache
# ---------------------------------------------------------------------------

def _swa_decode_kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, pos_ref, o_ref,
                       m_ref, d_ref, acc_ref, *,
                       bk: int, window: int, cache_len: int, n_k: int,
                       scale: float):
    """One grid step: q (1, G, hd) resident, sweep KV block j of the cache.

    No S x S tile walk — the grid is (N, C/bk) over KV blocks only; the
    single query row rides along in VMEM for the whole sweep, with the
    online-softmax (m, d, acc) carried in scratch exactly like the training
    forward. fp8 caches dequantize ON READ: k/v arrive as the stored payload
    and ks/vs carry the per-row scales (ones for dense caches), so the f32
    KV never exists in HBM. Ring masking derives each slot's absolute
    position from ``pos`` (slot = position % window) in-kernel; ``window ==
    0`` is the dense full-causal layout (slot s holds position s) and skips
    blocks past ``pos`` entirely.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0, 0]
    # dense mode: blocks whose first slot is past the query position hold
    # nothing visible — skip the compute (the ring mode visits every block:
    # capacity == window means every resident slot is in the band)
    run = (j * bk <= pos) if window == 0 else (j >= 0)

    @pl.when(run)
    def _tile():
        q = q_ref[0].astype(jnp.float32) * scale          # (G, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        k = k * ks_ref[0][:, None]
        v = v * vs_ref[0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        sl = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        if window:
            r = pos % window
            base = pos - r
            p = jnp.where(sl <= r, base + sl, base - window + sl)
            valid = (p >= 0) & (p <= pos) & (p > pos - window)
            valid &= sl < window                  # lane padding past C
        else:
            p = sl
            valid = (p <= pos) & (sl < cache_len)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                               # (G,)
        m_new = jnp.maximum(m_prev, s.max(-1))
        corr = jnp.exp(m_prev - m_new)
        pr = jnp.exp(s - m_new[:, None])
        d_ref[...] = d_ref[...] * corr + pr.sum(-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            pr, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        denom = jnp.maximum(d_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)[None]


def swa_flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                     k_scale: jax.Array, v_scale: jax.Array,
                     pos: jax.Array, *, window: int = 0,
                     cache_len: int = 0, bk: int = 128,
                     interpret: bool = False) -> jax.Array:
    """Single-query GQA flash decode. q (N, G, hd); k/v (N, Cp, hd) cache
    payload (fp8 or dense dtype, Cp = lane-padded capacity); k_scale/v_scale
    (N, Cp) f32 per-row dequant scales (ones for dense); pos (N, 1) i32.
    ``window`` > 0 = ring layout of capacity ``window``; 0 = dense cache of
    ``cache_len`` valid slots. Returns (N, G, hd) f32."""
    n, g, hd = q.shape
    cp = k.shape[1]
    bk_ = min(bk, cp)
    n_k = pl.cdiv(cp, bk_)
    grid = (n, n_k)
    scale = hd ** -0.5

    kv_spec = pl.BlockSpec((1, bk_, hd), lambda b, j: (b, j, 0))
    sc_spec = pl.BlockSpec((1, bk_), lambda b, j: (b, j))
    return pl.pallas_call(
        functools.partial(_swa_decode_kernel, bk=bk_, window=window,
                          cache_len=cache_len, n_k=n_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda b, j: (b, 0, 0)),
            kv_spec, sc_spec, kv_spec, sc_spec,
            pl.BlockSpec((1, 1), lambda b, j: (b, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, g, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),          # running max
            pltpu.VMEM((g,), jnp.float32),          # running denominator
            pltpu.VMEM((g, hd), jnp.float32),       # accumulator
        ],
        interpret=interpret,
    )(q, k, k_scale, v, v_scale, pos)


# ---------------------------------------------------------------------------
# training path: GQA-grouped forward with logsumexp residual + fused backward
# ---------------------------------------------------------------------------

def _swa_fwd_res_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                        m_ref, d_ref, acc_ref, *,
                        bq: int, bk: int, window: int, n_k: int,
                        seq_len: int, scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(_tile_in_range(qi, kj, bq=bq, bk=bk, window=window))
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale    # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _tile_mask(qi, kj, bq=bq, bk=bk, window=window,
                          seq_len=seq_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        d_ref[...] = d_ref[...] * corr + p.sum(-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        denom = jnp.maximum(d_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None]
                      ).astype(o_ref.dtype)[None, None]
        lse_ref[...] = (m_ref[...] + jnp.log(denom))[None, None]


def swa_flash_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int = 0, bq: int = 256, bk: int = 256,
                  interpret: bool = False):
    """GQA forward with residuals. q: (BKV, G, S, hd); k, v: (BKV, S, hd).
    Returns (out (BKV, G, S, hd), lse (BKV, G, S) f32)."""
    bkv, g, s, hd = q.shape
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    n_k = pl.cdiv(s, bk_)
    grid = (bkv, g, pl.cdiv(s, bq_), n_k)
    scale = hd ** -0.5

    return pl.pallas_call(
        functools.partial(_swa_fwd_res_kernel, bq=bq_, bk=bk_, window=window,
                          n_k=n_k, seq_len=s, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq_, hd), lambda b, g, i, j: (b, g, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, g, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, g, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq_, hd), lambda b, g, i, j: (b, g, i, 0)),
            pl.BlockSpec((1, 1, bq_), lambda b, g, i, j: (b, g, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bkv, g, s, hd), q.dtype),
            jax.ShapeDtypeStruct((bkv, g, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),        # running max
            pltpu.VMEM((bq_,), jnp.float32),        # running denominator
            pltpu.VMEM((bq_, hd), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def _bwd_tile_ds(q, k, v, do, delta, lse, qi, kj, *,
                 bq: int, bk: int, window: int, seq_len: int):
    """Shared dq/dkdv tile math: rebuild p from the lse residual, return
    (p, ds). Masked-out entries have s = NEG_INF so p (and hence ds) vanish
    without re-masking. q must arrive pre-scaled."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    mask = _tile_mask(qi, kj, bq=bq, bk=bk, window=window, seq_len=seq_len)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                   # (bq, bk)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    return p, ds


def _swa_bwd_dq_kernel(q_ref, k_ref, v_ref, lse_ref, delta_ref, do_ref,
                       dq_ref, acc_ref, *,
                       bq: int, bk: int, window: int, n_k: int,
                       seq_len: int, scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(_tile_in_range(qi, kj, bq=bq, bk=bk, window=window))
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        _, ds = _bwd_tile_ds(q, k, v, do, delta_ref[0, 0], lse_ref[0, 0],
                             qi, kj, bq=bq, bk=bk, window=window,
                             seq_len=seq_len)
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kj == n_k - 1)
    def _finalize():
        dq_ref[...] = (acc_ref[...] * scale).astype(dq_ref.dtype)[None, None]


def swa_flash_bwd_dq(q, k, v, lse, delta, do, *, window: int = 0,
                     bq: int = 256, bk: int = 256,
                     interpret: bool = False) -> jax.Array:
    """dq sweep: for each (group, Q tile), accumulate over visible KV tiles.
    Layouts as in :func:`swa_flash_fwd`; ``delta = rowsum(do * o)`` is
    precomputed by the caller (FlashAttention-2 style) so ``o`` never enters
    the kernel's input stream. Returns dq (BKV, G, S, hd) f32."""
    bkv, g, s, hd = q.shape
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    n_k = pl.cdiv(s, bk_)
    grid = (bkv, g, pl.cdiv(s, bq_), n_k)
    scale = hd ** -0.5

    q_spec = pl.BlockSpec((1, 1, bq_, hd), lambda b, g, i, j: (b, g, i, 0))
    kv_spec = pl.BlockSpec((1, bk_, hd), lambda b, g, i, j: (b, j, 0))
    row_spec = pl.BlockSpec((1, 1, bq_), lambda b, g, i, j: (b, g, i))
    return pl.pallas_call(
        functools.partial(_swa_bwd_dq_kernel, bq=bq_, bk=bk_, window=window,
                          n_k=n_k, seq_len=s, scale=scale),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, row_spec, row_spec, q_spec],
        out_specs=pl.BlockSpec((1, 1, bq_, hd), lambda b, g, i, j: (b, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bkv, g, s, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq_, hd), jnp.float32),     # dq accumulator
        ],
        interpret=interpret,
    )(q, k, v, lse, delta, do)


def _swa_bwd_dkdv_kernel(q_ref, k_ref, v_ref, lse_ref, delta_ref, do_ref,
                         dk_ref, dv_ref, dk_acc, dv_acc, *,
                         bq: int, bk: int, window: int, n_g: int, n_q: int,
                         seq_len: int, scale: float):
    kj = pl.program_id(1)
    gi = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when((gi == 0) & (qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(_tile_in_range(qi, kj, bq=bq, bk=bk, window=window))
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        p, ds = _bwd_tile_ds(q, k, v, do, delta_ref[0, 0], lse_ref[0, 0],
                             qi, kj, bq=bq, bk=bk, window=window,
                             seq_len=seq_len)
        # accumulate per KV head: every group head and every visible Q tile
        # lands in the same (bk, hd) accumulators
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        # q is pre-scaled, so ds^T @ q already carries the 1/sqrt(hd)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when((gi == n_g - 1) & (qi == n_q - 1))
    def _finalize():
        dk_ref[...] = dk_acc[...].astype(dk_ref.dtype)[None]
        dv_ref[...] = dv_acc[...].astype(dv_ref.dtype)[None]


def swa_flash_bwd_dkdv(q, k, v, lse, delta, do, *, window: int = 0,
                       bq: int = 256, bk: int = 256,
                       interpret: bool = False):
    """dk/dv sweep: for each KV tile, accumulate over the query-head group
    AND every visible Q tile (grid (BKV, S/bk, G, S/bq), Q innermost).
    ``delta`` precomputed as in :func:`swa_flash_bwd_dq`. Returns (dk, dv),
    both (BKV, S, hd) f32 — per KV head, unexpanded."""
    bkv, g, s, hd = q.shape
    bq_ = min(bq, s)
    bk_ = min(bk, s)
    n_q = pl.cdiv(s, bq_)
    grid = (bkv, pl.cdiv(s, bk_), g, n_q)
    scale = hd ** -0.5

    q_spec = pl.BlockSpec((1, 1, bq_, hd), lambda b, j, g, i: (b, g, i, 0))
    kv_spec = pl.BlockSpec((1, bk_, hd), lambda b, j, g, i: (b, j, 0))
    row_spec = pl.BlockSpec((1, 1, bq_), lambda b, j, g, i: (b, g, i))
    return pl.pallas_call(
        functools.partial(_swa_bwd_dkdv_kernel, bq=bq_, bk=bk_,
                          window=window, n_g=g, n_q=n_q, seq_len=s,
                          scale=scale),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec, row_spec, row_spec, q_spec],
        out_specs=[kv_spec, kv_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bkv, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((bkv, s, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk_, hd), jnp.float32),     # dk accumulator
            pltpu.VMEM((bk_, hd), jnp.float32),     # dv accumulator
        ],
        interpret=interpret,
    )(q, k, v, lse, delta, do)
