"""Kernel backend dispatch: one routing layer between the SP-NGD hot paths
and their implementations.

The paper's overhead argument (§5.2) rests on two hot spots — statistics
construction ``A = X^T X`` and preconditioning ``A^-1 dW G^-1`` — running at
hardware speed. This module owns the decision of *which* implementation runs:

* ``"ref"``    — the pure-``jnp`` einsum path (seed behaviour, bit-for-bit).
* ``"pallas"`` — the MXU-aligned Pallas kernels in this package. On CPU the
  kernels execute with ``interpret=True`` (numerics-exact emulation); on TPU
  they compile to real Mosaic kernels.
* ``"auto"``   — resolve per op and per shape: Pallas on TPU when the dims
  that predict the kernel's win are at least :data:`MIN_PALLAS_DIM`, ref
  everywhere else. Each op passes its own relevant dims to :func:`resolve`
  (matmul-shaped ops gate on their contraction dims — tiny dims cannot fill
  an MXU tile and lose to plain XLA; attention gates on sequence length
  only, being bandwidth- not MXU-bound). On CPU auto always resolves to
  ref, so it is semantics-preserving for tests.

Every public op here accepts the *blocked* factor layout used by the rest of
the framework — arrays of shape ``(lead..., nb, b, b)`` with arbitrary
leading layer/expert axes — and shims it down to the rank-2/rank-3 layouts
the kernels accept (``vmap`` for the SYRK kernel, a leading-axis collapse for
the block preconditioner, which treats its leading dim as an independent
grid axis anyway). f32 accumulation semantics are identical across backends:
inputs may be bf16, accumulation and outputs are f32.

Adding a new kernel
-------------------
Register an implementation for an existing op (or a new op name) with
:func:`register`::

    from repro.kernels import dispatch
    dispatch.register("factor_sum", "pallas", my_faster_impl)

An op resolved to a backend with no registered implementation falls back to
``"ref"`` (so e.g. ``backend="pallas"`` still trains end-to-end while ops are
ported one at a time); ``ref`` implementations are mandatory.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

BACKENDS = ("ref", "pallas", "auto")

# auto: smallest contraction dim worth handing to the MXU kernels. One MXU
# tile is 128x128; below that the kernel's padding outweighs its win.
MIN_PALLAS_DIM = 128

_TABLE: dict[str, dict[str, Callable]] = {}


def register(op: str, backend: str, fn: Callable) -> None:
    """Register ``fn`` as the ``backend`` implementation of ``op``."""
    _TABLE.setdefault(op, {})[backend] = fn


def lookup(op: str, backend: str) -> Callable:
    impls = _TABLE.get(op)
    if impls is None:
        raise KeyError(f"unregistered kernel op {op!r}; registered ops: "
                       f"{sorted(_TABLE)}")
    return impls.get(backend, impls["ref"])


def _call(op: str, which: str, *args, **kwargs):
    """Invoke the resolved implementation under a stable trace-viewer scope
    (``repro.kernels.<op>[<backend>]``, :func:`repro.obs.tracing
    .kernel_scope`) so a ref-vs-pallas A/B of the same op lines up by name
    in a captured profile. Kept as a separate step from :func:`lookup` so
    tests that spy on lookup still observe every dispatch."""
    from repro.obs.tracing import kernel_scope
    fn = lookup(op, which)
    with kernel_scope(op, which):
        return fn(*args, **kwargs)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve(backend: str | None, *dims: int) -> str:
    """Map a config knob to a concrete backend for one op instance.

    ``dims`` are the shape quantities that must be MXU-worthy for the Pallas
    path to pay off under ``"auto"`` (contraction dims, sequence length...).
    """
    backend = backend or "auto"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    if backend != "auto":
        return backend
    if not dims:
        # all(()) is True: a dims-less call would resolve to "pallas" on TPU
        # unconditionally, sidestepping the MXU-worthiness gate
        raise ValueError('resolve("auto") needs at least one shape dim '
                         "(the quantities that predict the Pallas win)")
    if _on_tpu() and all(d >= MIN_PALLAS_DIM for d in dims):
        return "pallas"
    return "ref"


# ---------------------------------------------------------------------------
# factor_sum: blocked A = sum_t x_t x_t^T     (..., n, d) -> (..., nb, b, b)
# ---------------------------------------------------------------------------

def _factor_sum_ref(x: jax.Array, max_dim: int) -> jax.Array:
    from repro.core import kfac
    d = x.shape[-1]
    xb = kfac.block_reshape(x, d, max_dim, axis=-1)
    return jnp.einsum("...nka,...nkb->...kab", xb, xb,
                      preferred_element_type=jnp.float32)


def _factor_sum_pallas(x: jax.Array, max_dim: int) -> jax.Array:
    from repro.core import kfac
    from repro.kernels import ops
    d = x.shape[-1]
    xb = kfac.block_reshape(x, d, max_dim, axis=-1)   # (..., n, nb, b)
    xb = jnp.moveaxis(xb, -2, -3)                     # (..., nb, n, b)
    lead = xb.shape[:-2]
    n, b = xb.shape[-2:]
    flat = xb.reshape((-1, n, b))
    out = jax.vmap(lambda m: ops.kfac_factor(m))(flat)
    return out.reshape(lead + (b, b))


def factor_sum(x: jax.Array, max_dim: int, *,
               backend: str | None = None) -> jax.Array:
    """Blocked raw factor sum; the §5.2 statistics-construction hot spot."""
    from repro.core import kfac
    b = kfac.block_size(x.shape[-1], max_dim)
    which = resolve(backend, b, x.shape[-2])
    return _call("factor_sum", which, x, max_dim)


# ---------------------------------------------------------------------------
# factor_sum_wire: fused factor sum + wire-format epilogue
#   (..., n, d) -> (payload fp8 (..., nb, t=b(b+1)/2), scale f32 (..., nb))
# The Stage-3 "fused" strategy's capture op: the pallas path emits the fp8
# wire tile straight out of the SYRK kernel's VMEM accumulator (the raw f32
# factor sum never reaches HBM); the ref path is the unfused composition
# factor_sum -> sym_pack -> quantize_rows, numerically equivalent up to f32
# accumulation order.
# ---------------------------------------------------------------------------

def _factor_sum_wire_ref(x, max_dim: int, fmt: str, scale_mode: str):
    from repro.core import kfac
    from repro.quant import quant
    f = _factor_sum_ref(x, max_dim)
    return quant.quantize_rows(kfac.sym_pack(f), fmt, scale_mode)


def _factor_sum_wire_pallas(x, max_dim: int, fmt: str, scale_mode: str):
    from repro.core import kfac
    from repro.kernels import ops
    d = x.shape[-1]
    b = kfac.block_size(d, max_dim)
    if b > ops.FACTOR_WIRE_MAX_DIM:
        return _factor_sum_wire_ref(x, max_dim, fmt, scale_mode)
    xb = kfac.block_reshape(x, d, max_dim, axis=-1)   # (..., n, nb, b)
    xb = jnp.moveaxis(xb, -2, -3)                     # (..., nb, n, b)
    lead = xb.shape[:-2]
    n = xb.shape[-2]
    flat = xb.reshape((-1, n, b))
    payload, scale = jax.vmap(
        lambda m: ops.kfac_factor_wire(m, fmt=fmt, scale_mode=scale_mode)
    )(flat)
    t = b * (b + 1) // 2
    return payload.reshape(lead + (t,)), scale.reshape(lead)


def factor_sum_wire(x: jax.Array, max_dim: int, *, fmt: str = "e4m3",
                    scale_mode: str = "fp32",
                    backend: str | None = None):
    """Fused statistics construction: blocked factor sum emitted directly
    in the sym-packed fp8 wire format (payload, per-block scale)."""
    from repro.core import kfac
    b = kfac.block_size(x.shape[-1], max_dim)
    which = resolve(backend, b, x.shape[-2])
    return _call("factor_sum_wire", which, x, max_dim, fmt, scale_mode)


# ---------------------------------------------------------------------------
# block_precond_left:  U[k] = Binv[k] @ W[k]
#   binv (..., nb, b, b), w (..., nb, b, m) -> (..., nb, b, m) f32
# ---------------------------------------------------------------------------

def _precond_left_ref(binv: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("...kab,...kbo->...kao", binv, w)


def _collapse_lead(binv, w):
    """Fold leading layer/expert axes into the kernel's block-grid axis —
    every (b x b) @ (b x m) product is independent, so (lead..., nb) can be
    flattened into one batch dim the kernel iterates as grid dim 0."""
    lead = binv.shape[:-2]
    b = binv.shape[-1]
    m = w.shape[-1]
    return binv.reshape((-1, b, b)), w.reshape((-1, b, m)), lead


def _precond_left_pallas(binv: jax.Array, w: jax.Array) -> jax.Array:
    from repro.kernels import ops
    bf, wf, lead = _collapse_lead(binv, w)
    out = ops.kfac_block_precond(bf, wf)
    return out.reshape(lead + out.shape[-2:])


def block_precond_left(binv: jax.Array, w: jax.Array, *,
                       backend: str | None = None) -> jax.Array:
    """Apply blocked inverse from the left (the ``A^-1 dW`` half)."""
    which = resolve(backend, binv.shape[-1], w.shape[-1])
    return _call("block_precond_left", which, binv, w)


# ---------------------------------------------------------------------------
# block_precond_right:  U[k] = W[k] @ Binv[k]
#   w (..., m, nb, b), binv (..., nb, b, b) -> (..., m, nb, b) f32
# ---------------------------------------------------------------------------

def _precond_right_ref(w: jax.Array, binv: jax.Array) -> jax.Array:
    return jnp.einsum("...iko,...kop->...ikp", w, binv)


def _precond_right_pallas(w: jax.Array, binv: jax.Array) -> jax.Array:
    # W @ Binv == (Binv^T @ W^T)^T per block: reuse the left kernel.
    wt = jnp.swapaxes(jnp.moveaxis(w, -3, -2), -1, -2)   # (..., nb, b, m)
    out = _precond_left_pallas(jnp.swapaxes(binv, -1, -2), wt)
    return jnp.moveaxis(jnp.swapaxes(out, -1, -2), -2, -3)


def block_precond_right(w: jax.Array, binv: jax.Array, *,
                        backend: str | None = None) -> jax.Array:
    """Apply blocked inverse from the right (the ``dW G^-1`` half)."""
    which = resolve(backend, binv.shape[-1], w.shape[-3])
    return _call("block_precond_right", which, w, binv)


# ---------------------------------------------------------------------------
# damped_inverse: (F + damping I)^-1 per block — the Stage-4 inversion.
#
# method "eigh" / "cholesky" are direct factorizations: not matmul-shaped,
# so they are ref-only and the pallas backend routes them straight to ref
# (the same op-by-op degradation as an unregistered op). method
# "newton_schulz" is matmul-only: ref = the jnp blocked iteration
# (kfac.newton_schulz_inverse), pallas = the VMEM-resident kernel
# (kernels/newton_schulz.py) — both share one failure contract: any block
# whose relative residual ||I - M X||_F / ||I||_F is still above ns_tol
# after ns_iters capped iterations is re-solved with the eigh path (and the
# event logged), so an ill-conditioned block can never silently ship a
# wrong inverse. Impl signature: fn(f, damping, method, ns_iters, ns_tol)
# -> (inv, res) with res (...,) per-block residual (zeros for the direct
# methods).
# ---------------------------------------------------------------------------

# the canonical iteration cap / residual tolerance live next to the
# algorithm (kfac is import-safe here: its own dispatch imports are lazy)
from repro.core.kfac import NS_ITERS, NS_TOL  # noqa: E402


def _ns_eigh_fallback(f, damping, x, res, ns_tol):
    """Replace blocks the iteration cannot be trusted on with the eigh
    inverse. Two triggers, both folded into the returned residual:

    * res > ns_tol — the capped iteration failed to contract;
    * min diag(X) <= 0 — an SPD inverse must have a strictly positive
      diagonal, so a non-positive entry means the damped factor was
      INDEFINITE (bf16-accumulation noise can push small eigenvalues
      negative). Newton-Schulz then converges to the true inverse of the
      indefinite matrix, but the framework's contract is eigh's clamped
      semantics (negative eigenvalues -> 0 before damping); those blocks
      must re-solve. Their residual is forced to +inf so callers reading
      ``ns_converged`` see them as fallbacks.

    The cond keeps the eigh work off the hot path when every block is
    trusted. Returns (x, res)."""
    from repro.core import kfac
    diag = jnp.diagonal(x, axis1=-2, axis2=-1)
    res = jnp.where(jnp.min(diag, axis=-1) > 0, res, jnp.inf)
    bad = res > ns_tol

    def fb(x):
        jax.debug.print("damped_inverse[newton_schulz]: {n} block(s) failed "
                        "to contract below tol={t} (or lost SPD); re-solved "
                        "via eigh", n=jnp.sum(bad), t=ns_tol)
        return jnp.where(bad[..., None, None],
                         kfac.damped_inverse(f, damping), x)

    return jax.lax.cond(jnp.any(bad), fb, lambda x: x, x), res


def _damped_inverse_ref(f, damping, method: str, ns_iters: int,
                        ns_tol: float):
    from repro.core import kfac
    if method == "newton_schulz":
        x, res = kfac.newton_schulz_inverse(f, damping, iters=ns_iters,
                                            tol=ns_tol)
        return _ns_eigh_fallback(f, damping, x, res, ns_tol)
    if method not in ("eigh", "cholesky"):
        raise ValueError(f"unknown inverse method {method!r}; expected "
                         "'eigh' | 'cholesky' | 'newton_schulz'")
    inv = kfac.damped_inverse if method == "eigh" else kfac.cholesky_inverse
    return inv(f, damping), jnp.zeros(f.shape[:-2], jnp.float32)


def _damped_inverse_pallas(f, damping, method: str, ns_iters: int,
                           ns_tol: float):
    from repro.kernels import ops
    if method != "newton_schulz":
        # direct methods degrade to ref in place
        return _damped_inverse_ref(f, damping, method, ns_iters, ns_tol)
    b = f.shape[-1]
    f32 = f.astype(jnp.float32)
    m = 0.5 * (f32 + jnp.swapaxes(f32, -1, -2))
    d = jnp.broadcast_to(jnp.asarray(damping, jnp.float32), f.shape[:-2])
    m = m + d[..., None, None] * jnp.eye(b, dtype=jnp.float32)
    lead = m.shape[:-2]
    # over-VMEM blocks run the two-level tiled kernel (HBM-resident
    # operands, VMEM tile loop per matmul) instead of degrading to ref
    kern = (ops.ns_inverse_tiled if b > ops.NS_KERNEL_MAX_DIM
            else ops.ns_inverse)
    x, res = kern(m.reshape((-1, b, b)), iters=ns_iters, tol=ns_tol)
    x = x.reshape(lead + (b, b))
    res = res.reshape(lead)
    return _ns_eigh_fallback(f, damping, x, res, ns_tol)


def damped_inverse(f: jax.Array, damping, *, method: str = "eigh",
                   ns_iters: int = NS_ITERS, ns_tol: float = NS_TOL,
                   backend: str | None = None, return_info: bool = False):
    """Stage-4 blocked damped inverse. With ``return_info=True`` also
    returns ``{"ns_res", "ns_converged"}`` per block — the test harness's
    (and any monitoring hook's) view of which blocks took the eigh
    fallback; for the direct methods the residual is identically zero."""
    which = resolve(backend, f.shape[-1])
    inv, res = _call("damped_inverse", which, f, damping, method,
                      ns_iters, ns_tol)
    if return_info:
        return inv, {"ns_res": res, "ns_converged": res <= ns_tol}
    return inv


# ---------------------------------------------------------------------------
# fp8_pack / fp8_unpack: symmetric blocked factor <-> sym-packed fp8 payload
#   f (..., b, b) -> (payload fp8 (..., t=b(b+1)/2), scale f32 (...,))
# One scale per block: the quantization tile IS the §5.2 communication tile,
# so the packed payload doubles as history storage and reduce-scatter message.
# ---------------------------------------------------------------------------

def _fp8_pack_ref(f, fmt: str, scale_mode: str):
    from repro.core import kfac
    from repro.quant import quant
    return quant.quantize_rows(kfac.sym_pack(f.astype(jnp.float32)),
                               fmt, scale_mode)


def _fp8_pack_pallas(f, fmt: str, scale_mode: str):
    # the tril gather is pure byte movement and stays on the XLA side (same
    # split as delta in ops.swa_attention_bwd); the kernel owns the numeric
    # pass (amax reduce + scale + clip + cast, one VMEM-resident sweep)
    from repro.core import kfac
    from repro.kernels import ops
    return ops.fp8_quant_rows(kfac.sym_pack(f.astype(jnp.float32)),
                              fmt=fmt, scale_mode=scale_mode)


def fp8_pack(f: jax.Array, *, fmt: str = "e4m3", scale_mode: str = "fp32",
             backend: str | None = None):
    """Quantize + sym-pack a symmetric blocked factor; §4.3 history and
    §5.2 payload compression on top of triangular packing."""
    which = resolve(backend, f.shape[-1])
    return _call("fp8_pack", which, f, fmt, scale_mode)


def _fp8_unpack_ref(payload, scale, b: int):
    from repro.core import kfac
    from repro.quant import quant
    return kfac.sym_unpack(quant.dequantize_rows(payload, scale), b)


def _fp8_unpack_pallas(payload, scale, b: int):
    from repro.core import kfac
    from repro.kernels import ops
    return kfac.sym_unpack(ops.fp8_dequant_rows(payload, scale), b)


def fp8_unpack(payload: jax.Array, scale: jax.Array, b: int, *,
               backend: str | None = None) -> jax.Array:
    """Dequantize-on-read: packed fp8 payload -> dense symmetric f32
    (..., b, b) blocks."""
    which = resolve(backend, b)
    return _call("fp8_unpack", which, payload, scale, b)


# ---------------------------------------------------------------------------
# ring_hop_pack / ring_hop_unpack: per-hop fp8 wire codec for the Stage-3
# ring reduce-scatter (repro.comm). Unlike fp8_pack/fp8_unpack these take
# rows that are ALREADY sym-packed (the hop payload is a chunk of packed
# triangles): (..., t) f32 <-> (payload fp8 (..., t), scale f32 (...,)),
# one scale per row — the quantization tile stays the §5.2 block tile, so
# the wire format matches the fp8 storage format bit for bit.
# ---------------------------------------------------------------------------

def _ring_hop_pack_ref(rows, fmt: str, scale_mode: str):
    from repro.quant import quant
    return quant.quantize_rows(rows, fmt, scale_mode)


def _ring_hop_pack_pallas(rows, fmt: str, scale_mode: str):
    from repro.kernels import ops
    return ops.fp8_quant_rows(rows, fmt=fmt, scale_mode=scale_mode)


def ring_hop_pack(rows: jax.Array, *, fmt: str = "e4m3",
                  scale_mode: str = "fp32", backend: str | None = None):
    """Quantize one ring hop's partial-sum rows to the fp8 wire format."""
    which = resolve(backend, rows.shape[-1])
    return _call("ring_hop_pack", which, rows, fmt, scale_mode)


def _ring_hop_unpack_ref(payload, scale):
    from repro.quant import quant
    return quant.dequantize_rows(payload, scale)


def _ring_hop_unpack_pallas(payload, scale):
    from repro.kernels import ops
    return ops.fp8_dequant_rows(payload, scale)


def ring_hop_unpack(payload: jax.Array, scale: jax.Array, *,
                    backend: str | None = None) -> jax.Array:
    """Dequantize a received hop payload back to the f32 accumulator."""
    which = resolve(backend, payload.shape[-1])
    return _call("ring_hop_unpack", which, payload, scale)


# ---------------------------------------------------------------------------
# swa_attention: causal sliding-window attention, (BH, S, hd) layout
# ---------------------------------------------------------------------------

def _swa_ref(q, k, v, window: int):
    from repro.kernels import ref
    return ref.swa_attention_ref(q, k, v, window=window)


def _swa_pallas(q, k, v, window: int):
    from repro.kernels import ops
    return ops.swa_attention(q, k, v, window=window)


def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int = 0, backend: str | None = None) -> jax.Array:
    # auto gates on seq only: flash attention's win is avoiding the (S, S)
    # score materialization (bandwidth-bound), not MXU tile fill, and the
    # standard head dims (64) would never pass the generic contraction-dim
    # threshold
    which = resolve(backend, q.shape[-2])
    return _call("swa_attention", which, q, k, v, window)


# ---------------------------------------------------------------------------
# swa_decode: single-query flash decode over a KV cache (the serving hot
# path). q (N, G, hd) in the GQA kernel layout (N = B * KV heads, G query
# heads per KV head, same grouping as the training ops); k/v (N, C, hd) are
# the CACHE contents — ``window > 0`` means C == window and the cache is a
# ring buffer (token at position p lives in slot p % window), ``window ==
# 0`` means a dense cache attended full-causally. pos (N,) i32 holds each
# sequence's absolute query position (== tokens already cached; the query's
# own k/v must be written before the call). k_scale/v_scale (N, C) f32 are
# optional per-row dequant scales for fp8 payloads — the pallas path
# dequantizes ON READ in VMEM, so the f32 cache never exists in HBM.
# ---------------------------------------------------------------------------

def _swa_decode_ref(q, k, v, pos, window: int, k_scale, v_scale):
    from repro.kernels import ref
    return ref.swa_decode_ref(q, k, v, pos, window=window,
                              k_scale=k_scale, v_scale=v_scale)


def _swa_decode_pallas(q, k, v, pos, window: int, k_scale, v_scale):
    from repro.kernels import ops
    return ops.swa_decode(q, k, v, pos, window=window,
                          k_scale=k_scale, v_scale=v_scale)


def swa_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array, *,
               window: int = 0, k_scale: jax.Array | None = None,
               v_scale: jax.Array | None = None,
               backend: str | None = None) -> jax.Array:
    """Single-query decode attention; returns (N, G, hd) f32."""
    # auto gates on cache capacity (the swept dim) — like swa_attention the
    # win is bandwidth, not MXU fill, and hd=64 would never pass the gate
    which = resolve(backend, k.shape[-2])
    return _call("swa_decode", which, q, k, v, pos, window, k_scale, v_scale)


# ---------------------------------------------------------------------------
# swa_attention_fwd_res / swa_attention_bwd: the training path.
#
# GQA layout contract: q / o / do are (BKV, G, S, hd) — query heads grouped
# by the KV head they attend through (head h = c*G + r maps to KV head c,
# matching models.attention._repeat_kv) — and k / v / dk / dv are
# (BKV, S, hd), i.e. KV is handed to the kernels UNEXPANDED. The forward
# also returns the per-row logsumexp residual lse (BKV, G, S) f32; the
# backward consumes (o, lse) instead of recomputing attention, and dk/dv
# come back accumulated per KV head across the whole query-head group.
# ---------------------------------------------------------------------------

def _swa_fwd_res_ref(q, k, v, window: int):
    from repro.kernels import ref
    return ref.swa_attention_fwd_res_ref(q, k, v, window=window)


def _swa_fwd_res_pallas(q, k, v, window: int):
    from repro.kernels import ops
    return ops.swa_attention_fwd_res(q, k, v, window=window)


def _swa_bwd_ref(q, k, v, o, lse, do, window: int):
    # the ref backward IS the recompute path: jax.vjp of the ref forward
    # (o / lse are unused), so "pallas" still degrades gracefully op-by-op
    from repro.kernels import ref

    def fwd(q, k, v):
        return ref.swa_attention_fwd_res_ref(q, k, v, window=window)[0]

    _, vjp = jax.vjp(fwd, q, k, v)
    dq, dk, dv = vjp(do.astype(o.dtype))
    return (dq.astype(jnp.float32), dk.astype(jnp.float32),
            dv.astype(jnp.float32))


def _swa_bwd_pallas(q, k, v, o, lse, do, window: int):
    from repro.kernels import ops
    return ops.swa_attention_bwd(q, k, v, o, lse, do, window=window)


def swa_attention_fwd_res(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          window: int = 0, backend: str | None = None):
    """Training forward: returns (out, lse) in the GQA layout above."""
    which = resolve(backend, q.shape[-2])
    return _call("swa_attention_fwd_res", which, q, k, v, window)


def swa_attention_bwd(q: jax.Array, k: jax.Array, v: jax.Array,
                      o: jax.Array, lse: jax.Array, do: jax.Array, *,
                      window: int = 0, backend: str | None = None):
    """Fused backward from residuals: returns (dq, dk, dv), all f32."""
    which = resolve(backend, q.shape[-2])
    return _call("swa_attention_bwd", which, q, k, v, o, lse, do, window)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

register("factor_sum", "ref", _factor_sum_ref)
register("factor_sum", "pallas", _factor_sum_pallas)
register("factor_sum_wire", "ref", _factor_sum_wire_ref)
register("factor_sum_wire", "pallas", _factor_sum_wire_pallas)
register("block_precond_left", "ref", _precond_left_ref)
register("block_precond_left", "pallas", _precond_left_pallas)
register("block_precond_right", "ref", _precond_right_ref)
register("block_precond_right", "pallas", _precond_right_pallas)
register("damped_inverse", "ref", _damped_inverse_ref)
register("damped_inverse", "pallas", _damped_inverse_pallas)
register("fp8_pack", "ref", _fp8_pack_ref)
register("fp8_pack", "pallas", _fp8_pack_pallas)
register("fp8_unpack", "ref", _fp8_unpack_ref)
register("fp8_unpack", "pallas", _fp8_unpack_pallas)
register("ring_hop_pack", "ref", _ring_hop_pack_ref)
register("ring_hop_pack", "pallas", _ring_hop_pack_pallas)
register("ring_hop_unpack", "ref", _ring_hop_unpack_ref)
register("ring_hop_unpack", "pallas", _ring_hop_unpack_pallas)
register("swa_attention", "ref", _swa_ref)
register("swa_attention", "pallas", _swa_pallas)
register("swa_decode", "ref", _swa_decode_ref)
register("swa_decode", "pallas", _swa_decode_pallas)
register("swa_attention_fwd_res", "ref", _swa_fwd_res_ref)
register("swa_attention_fwd_res", "pallas", _swa_fwd_res_pallas)
register("swa_attention_bwd", "ref", _swa_bwd_ref)
register("swa_attention_bwd", "pallas", _swa_bwd_pallas)
