"""Pallas TPU kernel: batched block preconditioning  U[k] = Binv[k] @ W[k].

The framework stores every Kronecker-factor inverse in *blocked* form
(nb, b, b) (DESIGN.md §4), so applying ``A^-1 dW`` (and symmetrically
``dW G^-1``) is a batch of (b x b) @ (b x m) products — one per diagonal
block. This kernel keeps the accumulator tile in VMEM across the inner
contraction sweep and accumulates in f32 regardless of input dtype.

Grid: (nb, b/bm, m/bn, b/bk); dims 0..2 are parallel, dim 3 accumulates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _precond_kernel(binv_ref, w_ref, out_ref):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    b = binv_ref[...].astype(jnp.float32)      # (1, bm, bk)
    w = w_ref[...].astype(jnp.float32)         # (1, bk, bn)
    out_ref[...] += jax.lax.dot_general(
        b[0], w[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]


def block_precond(binv: jax.Array, w: jax.Array, *, bm: int = 256,
                  bn: int = 256, bk: int = 256,
                  interpret: bool = False) -> jax.Array:
    """binv: (nb, b, b), w: (nb, b, m) -> (nb, b, m) f32."""
    nb, b, _ = binv.shape
    m = w.shape[-1]
    bm_ = min(bm, b)
    bn_ = min(bn, m)
    bk_ = min(bk, b)
    grid = (nb, pl.cdiv(b, bm_), pl.cdiv(m, bn_), pl.cdiv(b, bk_))

    return pl.pallas_call(
        _precond_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk_, bn_), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((nb, b, m), jnp.float32),
        interpret=interpret,
    )(binv, w)
