"""Pallas TPU kernels: per-row fp8 quantize / dequantize for packed factors.

These are the kernel half of the fp8 history / comm-payload subsystem
(:mod:`repro.quant`). The layout contract mirrors the SYRK epilogue: a
symmetric blocked factor ``(lead..., nb, b, b)`` sym-packs (XLA-side static
tril gather — pure byte movement, same division of labour as the ``delta``
rowsum in ``ops.swa_attention_bwd``) into rows of ``t = b(b+1)/2`` values,
and each kernel instance owns a tile of ``bg`` whole rows kept resident in
VMEM: amax reduction, scale, clip and fp8 cast happen in ONE pass over the
data — the fusion is quantize-with-its-own-scale, which XLA would otherwise
split into a reduce pass plus a rescale pass through HBM.

Rows are padded to the 128-lane boundary with zeros; zero padding is
amax-neutral (abs) and the wrappers in :mod:`repro.kernels.ops` slice it
off. A tile of ``bg`` rows must fit VMEM at ~5 bytes/element (f32 in +
fp8 out): the wrappers shrink ``bg`` so ``bg * t`` stays within a ~10 MB
tile budget (``ops._QUANT_TILE_ELEMS``), which reaches bg=1 exactly at
the largest row the framework produces (``max_dim`` 2048 -> t ≈ 2.1M);
anything beyond that would need a two-sweep (amax then quantize) variant.

Grid: (rows/bg,); one program per row tile, no revisit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_rows_kernel(x_ref, payload_ref, scale_ref, *, fmt_max: float,
                       pow2: bool):
    x = x_ref[...].astype(jnp.float32)                   # (bg, t)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)   # (bg, 1)
    # explicit reciprocal-multiply: bit-identical to the ref scale (see
    # quant.FMT_INV_MAX)
    s = amax * (1.0 / fmt_max)
    if pow2:
        s = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(s, 2.0 ** -126))))
    s = jnp.where(amax > 0, s, 1.0)
    scale_ref[...] = s
    q = jnp.clip(x / s, -fmt_max, fmt_max)   # e4m3fn overflows to NaN: clip
    payload_ref[...] = q.astype(payload_ref.dtype)


def quant_rows(x: jax.Array, fp8_dtype, *, fmt_max: float,
               pow2: bool = False, bg: int = 8,
               interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (g, t) f32/bf16 -> (payload (g, t) fp8, scale (g, 1) f32)."""
    g, t = x.shape
    bg_ = min(bg, g)
    grid = (pl.cdiv(g, bg_),)
    return pl.pallas_call(
        functools.partial(_quant_rows_kernel, fmt_max=fmt_max, pow2=pow2),
        grid=grid,
        in_specs=[pl.BlockSpec((bg_, t), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bg_, t), lambda i: (i, 0)),
            pl.BlockSpec((bg_, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, t), fp8_dtype),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def _dequant_rows_kernel(payload_ref, scale_ref, out_ref):
    out_ref[...] = payload_ref[...].astype(jnp.float32) * scale_ref[...]


def dequant_rows(payload: jax.Array, scale: jax.Array, *, bg: int = 8,
                 interpret: bool = False) -> jax.Array:
    """payload: (g, t) fp8, scale: (g, 1) f32 -> (g, t) f32."""
    g, t = payload.shape
    bg_ = min(bg, g)
    grid = (pl.cdiv(g, bg_),)
    return pl.pallas_call(
        _dequant_rows_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bg_, t), lambda i: (i, 0)),
            pl.BlockSpec((bg_, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bg_, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, t), jnp.float32),
        interpret=interpret,
    )(payload, scale)
