from repro.kernels.ops import (kfac_factor, kfac_block_precond,
                               swa_attention, swa_attention_fwd_res,
                               swa_attention_bwd)
from repro.kernels import dispatch
