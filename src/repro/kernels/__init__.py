from repro.kernels.ops import kfac_factor, kfac_block_precond, swa_attention
from repro.kernels import dispatch
