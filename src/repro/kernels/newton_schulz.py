"""Pallas TPU kernel: blocked Newton-Schulz damped inverse (Stage-4).

SP-NGD recomputes ``(F + lambda I)^-1`` per Kronecker-factor block on every
refresh step. Eigendecomposition / Cholesky are the one Stage-4 workload
that cannot ride the MXU (not matmul-shaped); the Newton-Schulz iteration

    X_{k+1} = X_k (2I - M X_k) = X_k + X_k (I - M X_k)

is nothing BUT matmuls, so this kernel moves the inversion onto the MXU.

Contract per grid instance (one factor block, fully VMEM-resident):

* input is the already-damped, already-symmetrized ``M = F + lambda I``
  (the XLA side owns damping/symmetrization — pure elementwise prep, the
  same division of labour as the ``delta`` rowsum in the attention
  backward);
* the initial iterate is the spectral-norm upper-bound scaling computed
  in-kernel from one pass over ``M``:

      X_0 = M / (||M||_1 ||M||_inf)

  (``M`` symmetric, so ``M^T = M``); ``||M||_1 ||M||_inf >= ||M||_2^2``
  places every eigenvalue of ``M X_0`` in (0, 1], making ``I - M X_0`` a
  contraction for SPD ``M``;
* the iteration runs under a ``fori_loop`` cap of ``iters``; each step
  measures the fixed-point residual ``||I - M X_k||_F / ||I||_F`` and
  freezes the iterate once it reaches ``tol`` (the early exit — further
  trips keep the converged X bit-stable);
* outputs are the final iterate AND its residual, so the dispatch layer
  can detect blocks that failed to contract (ill-conditioned under weak
  damping) and re-solve exactly those via the eigh path.

The whole block stays resident: M, X and the step temporary are
``3 * b^2 * 4`` bytes, which caps the kernel at b = 1024 against the
~16 MB/core VMEM (``ops.NS_KERNEL_MAX_DIM``); larger blocks route to the
jnp reference, where XLA tiles the matmuls itself.

Grid: (g,); one program per block, no revisit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ns_kernel(m_ref, x_ref, res_ref, *, iters: int, tol: float):
    m = m_ref[0].astype(jnp.float32)                 # (bp, bp)
    bp = m.shape[0]
    ri = jax.lax.broadcasted_iota(jnp.int32, (bp, bp), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (bp, bp), 1)
    eye = jnp.where(ri == ci, 1.0, 0.0).astype(jnp.float32)

    am = jnp.abs(m)
    n1 = jnp.max(jnp.sum(am, axis=0))                # max abs column sum
    ninf = jnp.max(jnp.sum(am, axis=1))              # max abs row sum
    # M is symmetric by contract, so M^T / (n1 * ninf) == M * inv_scale
    x = m * (1.0 / (n1 * ninf))
    rnorm = 1.0 / (bp ** 0.5)                        # 1 / ||I||_F, static

    def mm(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    def body(_, x):
        r = eye - mm(m, x)
        res = jnp.sqrt(jnp.sum(r * r)) * rnorm
        # early exit: once res <= tol the iterate freezes (any further
        # trips of the capped loop return X unchanged)
        return jnp.where(res > tol, x + mm(x, r), x)

    x = jax.lax.fori_loop(0, iters, body, x)
    # residual of the RETURNED iterate (the in-loop value lags one step);
    # the dispatch layer reads res > tol as "failed to contract"
    r = eye - mm(m, x)
    res_ref[...] = (jnp.sqrt(jnp.sum(r * r)) * rnorm).reshape(1, 1)
    x_ref[...] = x[None]


def ns_inverse_blocks(m: jax.Array, *, iters: int, tol: float,
                      interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """m: (g, bp, bp) f32 symmetric damped blocks ->
    (x (g, bp, bp) f32, res (g, 1) f32)."""
    g, bp, _ = m.shape
    grid = (g,)
    return pl.pallas_call(
        functools.partial(_ns_kernel, iters=iters, tol=tol),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bp, bp), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, bp, bp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, bp, bp), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(m)
