"""Pallas TPU kernel: blocked Newton-Schulz damped inverse (Stage-4).

SP-NGD recomputes ``(F + lambda I)^-1`` per Kronecker-factor block on every
refresh step. Eigendecomposition / Cholesky are the one Stage-4 workload
that cannot ride the MXU (not matmul-shaped); the Newton-Schulz iteration

    X_{k+1} = X_k (2I - M X_k) = X_k + X_k (I - M X_k)

is nothing BUT matmuls, so this kernel moves the inversion onto the MXU.

Contract per grid instance (one factor block, fully VMEM-resident):

* input is the already-damped, already-symmetrized ``M = F + lambda I``
  (the XLA side owns damping/symmetrization — pure elementwise prep, the
  same division of labour as the ``delta`` rowsum in the attention
  backward);
* the initial iterate is the spectral-norm upper-bound scaling computed
  in-kernel from one pass over ``M``:

      X_0 = M / (||M||_1 ||M||_inf)

  (``M`` symmetric, so ``M^T = M``); ``||M||_1 ||M||_inf >= ||M||_2^2``
  places every eigenvalue of ``M X_0`` in (0, 1], making ``I - M X_0`` a
  contraction for SPD ``M``;
* the iteration runs under a ``fori_loop`` cap of ``iters``; each step
  measures the fixed-point residual ``||I - M X_k||_F / ||I||_F`` and
  freezes the iterate once it reaches ``tol`` (the early exit — further
  trips keep the converged X bit-stable);
* outputs are the final iterate AND its residual, so the dispatch layer
  can detect blocks that failed to contract (ill-conditioned under weak
  damping) and re-solve exactly those via the eigh path.

The whole block stays resident: M, X and the step temporary are
``3 * b^2 * 4`` bytes, which caps the kernel at b = 1024 against the
~16 MB/core VMEM (``ops.NS_KERNEL_MAX_DIM``). Larger blocks run the
TWO-LEVEL tiled variant below (``ns_tiled_residual`` /
``ns_tiled_update``): the operands stay HBM-resident and each matmul of
the iteration walks a ``(bt, bt)`` VMEM tile grid — outer level = the
Newton-Schulz step sequencing (one ``fori_loop`` trip per iteration on
the XLA side, ``ops.ns_inverse_tiled``), inner level = the per-matmul
tile loop inside the kernels — so big blocks no longer fall back to the
jnp reference iteration.

Grid: (g,) for the VMEM-resident kernel (one program per block, no
revisit); (g, nt, nt, nt) for the tiled kernels (output tiles revisited
along the contraction axis, the standard accumulate-in-VMEM pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ns_kernel(m_ref, x_ref, res_ref, *, iters: int, tol: float):
    m = m_ref[0].astype(jnp.float32)                 # (bp, bp)
    bp = m.shape[0]
    ri = jax.lax.broadcasted_iota(jnp.int32, (bp, bp), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (bp, bp), 1)
    eye = jnp.where(ri == ci, 1.0, 0.0).astype(jnp.float32)

    am = jnp.abs(m)
    n1 = jnp.max(jnp.sum(am, axis=0))                # max abs column sum
    ninf = jnp.max(jnp.sum(am, axis=1))              # max abs row sum
    # M is symmetric by contract, so M^T / (n1 * ninf) == M * inv_scale
    x = m * (1.0 / (n1 * ninf))
    rnorm = 1.0 / (bp ** 0.5)                        # 1 / ||I||_F, static

    def mm(a, b):
        return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    def body(_, x):
        r = eye - mm(m, x)
        res = jnp.sqrt(jnp.sum(r * r)) * rnorm
        # early exit: once res <= tol the iterate freezes (any further
        # trips of the capped loop return X unchanged)
        return jnp.where(res > tol, x + mm(x, r), x)

    x = jax.lax.fori_loop(0, iters, body, x)
    # residual of the RETURNED iterate (the in-loop value lags one step);
    # the dispatch layer reads res > tol as "failed to contract"
    r = eye - mm(m, x)
    res_ref[...] = (jnp.sqrt(jnp.sum(r * r)) * rnorm).reshape(1, 1)
    x_ref[...] = x[None]


def ns_inverse_blocks(m: jax.Array, *, iters: int, tol: float,
                      interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """m: (g, bp, bp) f32 symmetric damped blocks ->
    (x (g, bp, bp) f32, res (g, 1) f32)."""
    g, bp, _ = m.shape
    grid = (g,)
    return pl.pallas_call(
        functools.partial(_ns_kernel, iters=iters, tol=tol),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bp, bp), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, bp, bp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, bp, bp), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(m)


# ---------------------------------------------------------------------------
# Two-level tiled variant: blocks past the VMEM cap. M and X stay
# HBM-resident; each Newton-Schulz matmul is its own pallas_call whose
# (g, nt, nt, nt) grid streams (bt, bt) tiles through VMEM — the output
# tile is revisited along the trailing contraction dim k and accumulated
# in place (it stays VMEM-resident across the k sweep because its index
# map ignores k). Step sequencing (freeze-on-converge, the iteration cap)
# lives in ops.ns_inverse_tiled's fori_loop.
# ---------------------------------------------------------------------------

def _mm(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _ns_resid_kernel(m_ref, x_ref, r_ref, ss_ref, *, nt: int, bt: int):
    """One (i, j, k) tile visit of R = I - M @ X, plus the squared
    Frobenius norm of R accumulated into ss (g, 1, 1) across all tiles."""
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)
    part = _mm(m_ref[0], x_ref[0])

    @pl.when(k == 0)
    def _init():
        # identity tile at global offsets (i*bt, j*bt): nonzero only when
        # the tile straddles the diagonal (i == j)
        ri = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 0) + i * bt
        ci = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 1) + j * bt
        eye = jnp.where(ri == ci, 1.0, 0.0).astype(jnp.float32)
        r_ref[...] = (eye - part)[None]

    @pl.when(k != 0)
    def _accum():
        r_ref[...] = r_ref[...] - part[None]

    @pl.when(k == nt - 1)
    def _norm():
        r = r_ref[0]
        ss = jnp.sum(r * r)
        first = jnp.logical_and(i == 0, j == 0)

        @pl.when(first)
        def _seed():
            ss_ref[...] = ss.reshape(1, 1, 1)

        @pl.when(jnp.logical_not(first))
        def _add():
            ss_ref[...] = ss_ref[...] + ss



def ns_tiled_residual(m: jax.Array, x: jax.Array, *, bt: int,
                      interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """R = I - M @ X over (g, bp, bp) HBM-resident blocks with a (bt, bt)
    VMEM tile loop; also returns ss (g, 1, 1) = ||R||_F^2 per block."""
    g, bp, _ = m.shape
    nt = bp // bt
    return pl.pallas_call(
        functools.partial(_ns_resid_kernel, nt=nt, bt=bt),
        grid=(g, nt, nt, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bt), lambda gi, i, j, k: (gi, i, k)),
            pl.BlockSpec((1, bt, bt), lambda gi, i, j, k: (gi, k, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bt), lambda gi, i, j, k: (gi, i, j)),
            pl.BlockSpec((1, 1, 1), lambda gi, i, j, k: (gi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, bp, bp), jnp.float32),
            jax.ShapeDtypeStruct((g, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(m, x)


def _ns_update_kernel(xij_ref, xik_ref, r_ref, o_ref):
    """One (i, j, k) tile visit of X' = X + X @ R (the same X streamed
    under two index maps: the addend tile (i, j) and the operand tile
    (i, k))."""
    k = pl.program_id(3)
    part = _mm(xik_ref[0], r_ref[0])

    @pl.when(k == 0)
    def _init():
        o_ref[...] = xij_ref[...] + part[None]

    @pl.when(k != 0)
    def _accum():
        o_ref[...] = o_ref[...] + part[None]


def ns_tiled_update(x: jax.Array, r: jax.Array, *, bt: int,
                    interpret: bool = False) -> jax.Array:
    """X' = X + X @ R over (g, bp, bp) HBM-resident blocks."""
    g, bp, _ = x.shape
    nt = bp // bt
    return pl.pallas_call(
        _ns_update_kernel,
        grid=(g, nt, nt, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bt), lambda gi, i, j, k: (gi, i, j)),
            pl.BlockSpec((1, bt, bt), lambda gi, i, j, k: (gi, i, k)),
            pl.BlockSpec((1, bt, bt), lambda gi, i, j, k: (gi, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bt, bt), lambda gi, i, j, k: (gi, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, bp, bp), jnp.float32),
        interpret=interpret,
    )(x, x, r)
