"""Momentum-SGD baseline (the paper's first-order reference, Eq. 2).

Same heavy-ball form as the NGD update (Eq. 23) with the identity
preconditioner, so NGD-vs-SGD benchmark comparisons isolate the
preconditioning itself.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


class SGD:
    def __init__(self, loss_fn: Callable, weight_decay: float = 0.0):
        self.loss_fn = loss_fn
        self.weight_decay = weight_decay

    def init(self, params) -> dict:
        return {"step": jnp.zeros((), jnp.int32),
                "velocity": jax.tree.map(jnp.zeros_like, params)}

    def step(self, params, state, batch, lr, mom):
        (loss, aux), grads = jax.value_and_grad(
            self.loss_fn, has_aux=True)(params, None, batch)
        if self.weight_decay:
            grads = jax.tree.map(lambda g, w: g + self.weight_decay * w,
                                 grads, params)
        vel = jax.tree.map(lambda v, g: mom * v - lr * g, state["velocity"], grads)
        new_params = jax.tree.map(lambda w, v: w + v.astype(w.dtype), params, vel)
        metrics = {"loss": loss}
        return new_params, {"step": state["step"] + 1, "velocity": vel}, metrics
