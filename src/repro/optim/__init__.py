from repro.optim.schedules import polynomial_decay, coupled_momentum
from repro.optim.sgd import SGD
