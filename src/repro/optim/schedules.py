"""Learning-rate / momentum schedules (paper §6.2, Eq. 21-22)."""

from __future__ import annotations


def polynomial_decay(eta0: float, e_start: float, e_end: float,
                     p_decay: float):
    """Paper Eq. 21: eta(e) = eta0 * (1 - (e - e_start)/(e_end - e_start))^p.

    Flat at eta0 before e_start, 0 after e_end. ``e`` may be fractional
    (epoch = step * batch / dataset)."""
    span = e_end - e_start

    def schedule(e: float) -> float:
        if e <= e_start:
            return eta0
        if e >= e_end:
            return 0.0
        return eta0 * (1.0 - (e - e_start) / span) ** p_decay

    return schedule


def coupled_momentum(m0: float, eta0: float):
    """Paper Eq. 22: m(e) = (m0/eta0) * eta(e) — keeps m/eta constant so the
    momentum term does not dominate as the polynomial decay collapses eta."""
    ratio = m0 / eta0

    def schedule(eta: float) -> float:
        return ratio * eta

    return schedule


def warmup_polynomial(eta0: float, warmup_epochs: float, e_start: float,
                      e_end: float, p_decay: float):
    """Linear warmup into the polynomial decay (the paper starts decay at
    e_start >= 1, i.e. the first epoch(s) run at eta0; large-batch SGD
    baselines use gradual warmup [3] — provided for the SGD reference)."""
    poly = polynomial_decay(eta0, e_start, e_end, p_decay)

    def schedule(e: float) -> float:
        if e < warmup_epochs:
            return eta0 * (e / max(warmup_epochs, 1e-9))
        return poly(e)

    return schedule
